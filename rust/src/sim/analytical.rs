//! Analytical (closed-form) simulator — paper §4.1.
//!
//! Per-operation roofline at instruction granularity:
//! `T_op = max(T_cmp, T_mem)` with two concurrently accessed SRAM paths
//! (Matrix SRAM: weights/KV; Vector SRAM: activations), each bounded by
//! on-chip port bandwidth and the HBM spec. Per-phase memory strategies
//! follow the paper: warm steps stream weights for `M = B × L_tot`
//! tokens; refinement steps keep KV resident and process the
//! cache-mode-dependent token window;
//! `T_block = T_warm + (steps−1) · T_refine`.
//!
//! The sampling stage models Alg. 2 over Z ∈ R^{B×L×V}: when V_chunk
//! < V the double-buffered chunk stream overlaps HBM with the vector
//! reductions (roofline max); at V_chunk = V the single resident buffer
//! serializes the two passes (sum) — matching the cycle simulator's
//! behaviour (Table 4 cross-validation within a few percent).
//!
//! ~10⁴–10⁵× faster than the cycle simulator, making it the DSE tool
//! for Fig. 9 / Table 6.

use crate::config::{CacheMode, HwConfig, Workload};
use crate::quant::MxFormat;
use crate::sampling::SamplePrecision;
use crate::sim::power::{area, AreaReport, EnergyModel, EnergyReport};

/// Quantization configuration of the datapath (paper Table 6 ‡: MXINT4
/// weights/KV, MXINT8 activations, BF16 sampling).
#[derive(Clone, Copy, Debug)]
pub struct PrecisionConfig {
    pub weights: MxFormat,
    pub kv: MxFormat,
    pub activations: MxFormat,
    pub sampling: SamplePrecision,
}

impl PrecisionConfig {
    pub fn dart_full_quant() -> Self {
        PrecisionConfig {
            weights: MxFormat::MxInt4,
            kv: MxFormat::MxInt4,
            activations: MxFormat::MxInt8,
            sampling: SamplePrecision::Bf16,
        }
    }

    pub fn bf16() -> Self {
        PrecisionConfig {
            weights: MxFormat::Bf16,
            kv: MxFormat::Bf16,
            activations: MxFormat::Bf16,
            sampling: SamplePrecision::Fp64,
        }
    }
}

/// One phase's latency + traffic accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseReport {
    pub seconds: f64,
    pub macs: f64,
    pub hbm_bytes: f64,
    pub sram_bytes: f64,
    pub vector_ops: f64,
}

impl PhaseReport {
    fn add(&mut self, o: PhaseReport) {
        self.seconds += o.seconds;
        self.macs += o.macs;
        self.hbm_bytes += o.hbm_bytes;
        self.sram_bytes += o.sram_bytes;
        self.vector_ops += o.vector_ops;
    }

    fn scaled(mut self, n: f64) -> PhaseReport {
        self.seconds *= n;
        self.macs *= n;
        self.hbm_bytes *= n;
        self.sram_bytes *= n;
        self.vector_ops *= n;
        self
    }
}

/// Full-run report (the Table 6 row shape).
#[derive(Clone, Copy, Debug)]
pub struct RunReport {
    pub model: PhaseReport,
    pub sampling: PhaseReport,
    pub total_s: f64,
    pub tps: f64,
    pub energy: EnergyReport,
    pub tok_per_j: f64,
    pub sampling_frac: f64,
}

impl RunReport {
    /// Emit this run's Fig-1-style phase breakdown into an `obs`
    /// recorder: back-to-back `sim.model` / `sim.sampling` spans on the
    /// simulated-time axis starting at virtual second `vt0`, plus
    /// per-phase byte/op counters. `sim.sampling.hbm_bytes` is the
    /// vocabulary-wide logit-buffer traffic the paper's Fig. 1
    /// attributes the sampling bottleneck to. Returns the virtual end
    /// time so callers can chain consecutive runs onto one timeline.
    pub fn record(&self, rec: &mut crate::obs::Recorder, vt0: f64) -> f64 {
        let m_end = vt0 + self.model.seconds;
        rec.span_closed("sim", "model", vt0, m_end);
        let s_end = m_end + self.sampling.seconds;
        rec.span_closed("sim", "sampling", m_end, s_end);
        rec.count("sim.model.macs", self.model.macs);
        rec.count("sim.model.hbm_bytes", self.model.hbm_bytes);
        rec.count("sim.model.sram_bytes", self.model.sram_bytes);
        rec.count("sim.sampling.hbm_bytes", self.sampling.hbm_bytes);
        rec.count("sim.sampling.sram_bytes", self.sampling.sram_bytes);
        rec.count("sim.sampling.vector_ops", self.sampling.vector_ops);
        s_end
    }
}

pub struct AnalyticalSim {
    pub hw: HwConfig,
    pub prec: PrecisionConfig,
    energy_model: EnergyModel,
}

impl AnalyticalSim {
    pub fn new(hw: HwConfig, prec: PrecisionConfig) -> Self {
        let energy_model = EnergyModel::asap7(&hw);
        AnalyticalSim { hw, prec, energy_model }
    }

    pub fn area(&self) -> AreaReport {
        area(&self.hw)
    }

    /// Systolic utilization vs the token dimension M: output-stationary
    /// arrays lose utilization on small M (tile fill/drain and ragged
    /// edges) — the effect that makes dual-cache refinement (M = B·L)
    /// relatively *worse* for DART than for GPUs (paper Table 6: H100
    /// overtakes DART only under dual cache).
    fn util(&self, m: f64) -> f64 {
        let m_half = 12.0 * self.hw.blen as f64; // fill/drain knee
        0.97 * m / (m + m_half)
    }

    /// One transformer forward over `m` tokens with `kv_len` span.
    fn forward(&self, w: &Workload, m: u64, kv_len: u64, warm: bool)
               -> PhaseReport {
        let a = &w.model;
        let macs = a.fwd_flops(m, kv_len) as f64 / 2.0;
        let peak = self.hw.total_pes() as f64 * self.hw.clock_hz;
        let t_cmp = macs / (peak * self.util(m as f64));

        // memory: weights streamed every pass (MoE: active experts);
        // KV read once per pass; new KV written on warm/active positions
        let w_bytes = a.weight_bytes(self.prec.weights.bits()) as f64;
        let kv_read = a.kv_bytes(w.batch, kv_len, self.prec.kv.bits()) as f64;
        let kv_write = a.kv_bytes(w.batch, if warm { kv_len } else { m / w.batch },
                                  self.prec.kv.bits()) as f64;
        let logits = (m * a.vocab) as f64
            * self.prec.activations.effective_bits() / 8.0;
        let hbm_bytes = w_bytes + kv_read + kv_write + logits;
        let t_mem = hbm_bytes / self.hw.hbm.peak_bw();

        // activations through Vector SRAM (two ports, overlapped)
        let act_bytes = (m * a.d_model * a.n_layers) as f64 * 6.0;
        PhaseReport {
            seconds: t_cmp.max(t_mem),
            macs,
            hbm_bytes,
            sram_bytes: act_bytes + w_bytes,
            vector_ops: (m * a.d_model * a.n_layers) as f64 * 4.0,
        }
    }

    /// One Alg. 2 sampling pass over Z ∈ R^{B×L×V}.
    pub fn sampling_step(&self, b: u64, l: u64, v: u64) -> PhaseReport {
        let positions = (b * l) as f64;
        let vlen = self.hw.vlen as f64;
        let clock = self.hw.clock_hz;
        let elem_bytes = match self.prec.sampling {
            SamplePrecision::Fp64 => 8.0,
            SamplePrecision::Fp32 => 4.0,
            SamplePrecision::Bf16 => 2.0,
            SamplePrecision::MxFp8 => 1.0,
        };
        let v_chunk = if self.hw.v_chunk == 0 { v } else { self.hw.v_chunk as u64 };
        let chunked = v_chunk < v;
        // per-pass compute: pass 1 is the fused max-with-index reduction
        // (comparator tree tail); pass 2 is V_ADD_VS + V_EXP_V +
        // V_RED_SUM, each a VLEN-lane sweep with pipeline fill
        let lanes = (v as f64 / vlen).ceil();
        let tree = (vlen.log2().ceil() + 1.0).max(1.0);
        let pass1_cmp = (lanes + tree) / clock;
        let pass2_cmp = 3.0 * (lanes + 6.0) / clock;
        // per-pass HBM: the logit row is streamed once per pass
        let bw = self.hw.hbm.peak_bw().min(
            // Vector SRAM port bound: VLEN lanes x 2B/cycle
            vlen * 2.0 * clock);
        let mem_pass = v as f64 * elem_bytes / bw;
        let bytes_pos = 2.0 * v as f64 * elem_bytes;
        let t_pos = if chunked {
            // double-buffered chunks: each pass overlaps its stream
            pass1_cmp.max(mem_pass) + pass2_cmp.max(mem_pass)
        } else {
            // single resident buffer: transfer and compute serialize
            pass1_cmp + pass2_cmp + 2.0 * mem_pass
        };
        // phases 3–4: top-k (L cycles) + masked updates per row
        let t_epilogue = (b as f64) * (l as f64 + 40.0) / clock;
        PhaseReport {
            seconds: positions * t_pos + t_epilogue,
            macs: 0.0,
            hbm_bytes: positions * bytes_pos,
            sram_bytes: positions * bytes_pos,
            vector_ops: positions * 2.0 * v as f64,
        }
    }

    /// Execute the blocked-diffusion workload; `T_block = T_warm +
    /// (steps−1)·T_refine` per generation block.
    pub fn run(&self, w: &Workload) -> RunReport {
        self.run_scheduled(w, w.steps_per_block as f64)
    }

    /// Execute the workload billing `steps_per_block` *realized* steps
    /// per block instead of the configured cap — the steps-aware cost
    /// path for adaptive denoising schedules
    /// ([`crate::schedule::ScheduleSpec::expected_steps`]). Fractional
    /// step counts are meaningful: an expectation of 9.25 steps bills a
    /// quarter refine more than 9. Clamped to `[1, w.steps_per_block]`
    /// (a block always runs its warm step); at exactly the configured
    /// cap this is bit-identical to [`Self::run`].
    pub fn run_scheduled(&self, w: &Workload, steps_per_block: f64)
                         -> RunReport {
        let cap = w.steps_per_block as f64;
        let steps = if cap >= 1.0 {
            steps_per_block.clamp(1.0, cap)
        } else {
            // degenerate zero-step geometry: preserve the legacy
            // warm-only accounting
            0.0
        };
        let l_tot = w.total_len();
        let mut model = PhaseReport::default();
        let mut sampling = PhaseReport::default();
        for blk in 0..w.n_blocks() {
            let s_n = w.prompt_len + blk * w.block_len;
            // warm step: full sequence, weights streamed
            model.add(self.forward(w, w.batch * l_tot, l_tot, true));
            let refines = (steps - 1.0).max(0.0);
            let refine = match w.cache {
                CacheMode::None =>
                    self.forward(w, w.batch * l_tot, l_tot, true),
                CacheMode::Prefix =>
                    self.forward(w, w.batch * (l_tot - s_n), l_tot, false),
                CacheMode::Dual =>
                    self.forward(w, w.batch * w.block_len, l_tot, false),
            };
            model.add(refine.scaled(refines));
            sampling.add(self.sampling_step(w.batch, w.block_len,
                                            w.model.vocab)
                         .scaled(steps));
        }
        let total = model.seconds + sampling.seconds;
        let tokens = w.tokens_out() as f64;
        let energy = EnergyReport::compute(
            &self.energy_model,
            model.macs + sampling.macs,
            model.vector_ops + sampling.vector_ops,
            model.sram_bytes + sampling.sram_bytes,
            model.hbm_bytes + sampling.hbm_bytes,
            total);
        RunReport {
            model,
            sampling,
            total_s: total,
            tps: tokens / total,
            energy,
            tok_per_j: tokens / energy.total_j,
            sampling_frac: sampling.seconds / total,
        }
    }

    /// Cost of serving one refine step entirely from the feature cache:
    /// no transformer body, no output head — the cached active-block
    /// logits are restreamed to the sampler.
    fn reuse_step(&self, w: &Workload) -> PhaseReport {
        let m = w.batch * w.block_len;
        let logits = (m * w.model.vocab) as f64
            * self.prec.activations.effective_bits() / 8.0;
        PhaseReport {
            seconds: logits / self.hw.hbm.peak_bw(),
            macs: 0.0,
            hbm_bytes: logits,
            sram_bytes: logits,
            vector_ops: 0.0,
        }
    }

    /// [`Self::run_scheduled`] under a cross-step feature cache: bill
    /// only the *refreshed* fraction of feature work
    /// ([`crate::cache::CachePlan`], the S10 expectation). Per block:
    /// the block-start step mixes the full warm forward (fraction
    /// `warm_full_frac`, always 1.0 for the first block) with the
    /// cross-block refine pass; refine steps mix the cache-mode refine
    /// forward (fraction `refresh_frac`) with a logit-restream reuse
    /// step. Sampling runs every step regardless — the cache saves
    /// model forwards, never sampling passes.
    ///
    /// With `CachePlan::off()` (`{1.0, 1.0}` — also the
    /// `Interval {1, 1}` plan) every mix weight is exactly 1.0 or 0.0,
    /// so this is bit-identical to [`Self::run_scheduled`]
    /// (`rust/tests/cache_equivalence.rs` pins it).
    pub fn run_cached(&self, w: &Workload, steps_per_block: f64,
                      plan: &crate::cache::CachePlan) -> RunReport {
        let cap = w.steps_per_block as f64;
        let steps = if cap >= 1.0 {
            steps_per_block.clamp(1.0, cap)
        } else {
            0.0
        };
        let l_tot = w.total_len();
        let mut model = PhaseReport::default();
        let mut sampling = PhaseReport::default();
        for blk in 0..w.n_blocks() {
            let s_n = w.prompt_len + blk * w.block_len;
            let warm = self.forward(w, w.batch * l_tot, l_tot, true);
            if blk == 0 {
                // the first block's prompt features are always cold
                model.add(warm);
            } else {
                model.add(warm.scaled(plan.warm_full_frac));
                // cross-block prompt-feature reuse serves the block
                // start from the refine-shaped forward instead
                let warm_reuse =
                    self.forward(w, w.batch * w.block_len, l_tot, false);
                model.add(warm_reuse.scaled(1.0 - plan.warm_full_frac));
            }
            let refines = (steps - 1.0).max(0.0);
            let refine = match w.cache {
                CacheMode::None =>
                    self.forward(w, w.batch * l_tot, l_tot, true),
                CacheMode::Prefix =>
                    self.forward(w, w.batch * (l_tot - s_n), l_tot, false),
                CacheMode::Dual =>
                    self.forward(w, w.batch * w.block_len, l_tot, false),
            };
            model.add(refine.scaled(refines * plan.refresh_frac));
            model.add(self.reuse_step(w)
                      .scaled(refines * (1.0 - plan.refresh_frac)));
            sampling.add(self.sampling_step(w.batch, w.block_len,
                                            w.model.vocab)
                         .scaled(steps));
        }
        let total = model.seconds + sampling.seconds;
        let tokens = w.tokens_out() as f64;
        let energy = EnergyReport::compute(
            &self.energy_model,
            model.macs + sampling.macs,
            model.vector_ops + sampling.vector_ops,
            model.sram_bytes + sampling.sram_bytes,
            model.hbm_bytes + sampling.hbm_bytes,
            total);
        RunReport {
            model,
            sampling,
            total_s: total,
            tps: tokens / total,
            energy,
            tok_per_j: tokens / energy.total_j,
            sampling_frac: sampling.seconds / total,
        }
    }

    /// [`Self::run_cached`] under a suffix window: per block, the
    /// model-side phases are scaled by
    /// [`crate::window::window_cost_frac`] of the block's active-suffix
    /// fraction (`active_suffix_len / remaining` at that block's
    /// remaining masked suffix — the S12 closed form). Sampling over
    /// the active block runs every step regardless: the window narrows
    /// suffix-wide logit traffic and confidence scoring, never the
    /// block being committed.
    ///
    /// With [`crate::window::WindowPolicySpec::Full`] every per-block
    /// fraction is exactly 1.0 (`x / x`) and
    /// `window_cost_frac(1.0) == 1.0` exactly, so this is bit-identical
    /// to [`Self::run_cached`] (`rust/tests/window_equivalence.rs` pins
    /// it).
    pub fn run_windowed(&self, w: &Workload, steps_per_block: f64,
                        plan: &crate::cache::CachePlan,
                        window: &crate::window::WindowPolicySpec)
                        -> RunReport {
        let cap = w.steps_per_block as f64;
        let steps = if cap >= 1.0 {
            steps_per_block.clamp(1.0, cap)
        } else {
            0.0
        };
        let l_tot = w.total_len();
        let mut model = PhaseReport::default();
        let mut sampling = PhaseReport::default();
        for blk in 0..w.n_blocks() {
            let s_n = w.prompt_len + blk * w.block_len;
            // remaining masked suffix at this block (the block being
            // denoised included), and the window's cost fraction for it
            let remaining = ((w.n_blocks() - blk) * w.block_len) as usize;
            let wf = if remaining == 0 {
                1.0
            } else {
                crate::window::window_cost_frac(
                    window.active_suffix_len(remaining) as f64
                        / remaining as f64)
            };
            let warm = self.forward(w, w.batch * l_tot, l_tot, true);
            if blk == 0 {
                model.add(warm.scaled(wf));
            } else {
                model.add(warm.scaled(plan.warm_full_frac * wf));
                let warm_reuse =
                    self.forward(w, w.batch * w.block_len, l_tot, false);
                model.add(warm_reuse
                          .scaled((1.0 - plan.warm_full_frac) * wf));
            }
            let refines = (steps - 1.0).max(0.0);
            let refine = match w.cache {
                CacheMode::None =>
                    self.forward(w, w.batch * l_tot, l_tot, true),
                CacheMode::Prefix =>
                    self.forward(w, w.batch * (l_tot - s_n), l_tot, false),
                CacheMode::Dual =>
                    self.forward(w, w.batch * w.block_len, l_tot, false),
            };
            model.add(refine.scaled(refines * plan.refresh_frac * wf));
            model.add(self.reuse_step(w)
                      .scaled(refines * (1.0 - plan.refresh_frac) * wf));
            sampling.add(self.sampling_step(w.batch, w.block_len,
                                            w.model.vocab)
                         .scaled(steps));
        }
        let total = model.seconds + sampling.seconds;
        let tokens = w.tokens_out() as f64;
        let energy = EnergyReport::compute(
            &self.energy_model,
            model.macs + sampling.macs,
            model.vector_ops + sampling.vector_ops,
            model.sram_bytes + sampling.sram_bytes,
            model.hbm_bytes + sampling.hbm_bytes,
            total);
        RunReport {
            model,
            sampling,
            total_s: total,
            tps: tokens / total,
            energy,
            tok_per_j: tokens / energy.total_j,
            sampling_frac: sampling.seconds / total,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CacheMode, HwConfig, ModelArch, Workload};

    fn dart(cache: CacheMode) -> RunReport {
        let w = Workload::paper_reference(ModelArch::llada_8b(), cache);
        AnalyticalSim::new(HwConfig::dart_default(),
                           PrecisionConfig::dart_full_quant()).run(&w)
    }

    #[test]
    fn cache_mode_throughput_ordering() {
        let none = dart(CacheMode::None);
        let prefix = dart(CacheMode::Prefix);
        let dual = dart(CacheMode::Dual);
        assert!(dual.tps > prefix.tps, "dual {} prefix {}", dual.tps, prefix.tps);
        assert!(prefix.tps > none.tps, "prefix {} none {}", prefix.tps, none.tps);
    }

    #[test]
    fn dart_beats_a6000_tps_and_energy() {
        use crate::gpu::GpuSpec;
        for cache in CacheMode::ALL {
            let d = dart(cache);
            let w = Workload::paper_reference(ModelArch::llada_8b(), cache);
            let g = GpuSpec::a6000().run(&w, SamplePrecision::Bf16);
            let tps_x = d.tps / g.tps;
            let ej_x = d.tok_per_j / g.tok_per_j;
            assert!(tps_x > 1.5 && tps_x < 12.0,
                    "{cache:?} tps x{tps_x:.2}");
            assert!(ej_x > 5.0 && ej_x < 60.0, "{cache:?} tok/J x{ej_x:.2}");
        }
    }

    #[test]
    fn h100_wins_only_dual() {
        // the paper's crossover: DART > H100 on None/Prefix (large-M,
        // bandwidth-friendly), H100 > DART on Dual (small-M refinement)
        use crate::gpu::GpuSpec;
        let rel = |cache| {
            let d = dart(cache);
            let w = Workload::paper_reference(ModelArch::llada_8b(), cache);
            let h = GpuSpec::h100().run(&w, SamplePrecision::Bf16);
            d.tps / h.tps
        };
        assert!(rel(CacheMode::None) > 1.0, "none {}", rel(CacheMode::None));
        assert!(rel(CacheMode::Prefix) > 1.0, "prefix {}", rel(CacheMode::Prefix));
        assert!(rel(CacheMode::Dual) < 1.1, "dual {}", rel(CacheMode::Dual));
    }

    #[test]
    fn sampling_under_10pct_at_reduced_precision() {
        let r = dart(CacheMode::Dual);
        assert!(r.sampling_frac < 0.10, "frac {}", r.sampling_frac);
    }

    #[test]
    fn sampling_scales_linearly() {
        let sim = AnalyticalSim::new(HwConfig::dart_edge(),
                                     PrecisionConfig::dart_full_quant());
        let t1 = sim.sampling_step(2, 64, 32_000).seconds;
        let t2 = sim.sampling_step(4, 64, 32_000).seconds;
        let t3 = sim.sampling_step(2, 64, 64_000).seconds;
        assert!((t2 / t1 - 2.0).abs() < 0.2, "B scaling {}", t2 / t1);
        assert!((t3 / t1 - 2.0).abs() < 0.3, "V scaling {}", t3 / t1);
    }

    #[test]
    fn vchunk_saturation() {
        // Fig. 7(d): larger V_chunk helps until ~4k then saturates
        let mut hw_small = HwConfig::dart_edge();
        hw_small.v_chunk = 128;
        let mut hw_big = hw_small.clone();
        hw_big.v_chunk = 8192;
        let p = PrecisionConfig::dart_full_quant();
        let t_small = AnalyticalSim::new(hw_small, p)
            .sampling_step(2, 64, 128_000).seconds;
        let t_big = AnalyticalSim::new(hw_big, p)
            .sampling_step(2, 64, 128_000).seconds;
        assert!(t_big <= t_small * 1.01);
    }

    #[test]
    fn moe_much_faster_than_dense() {
        let p = PrecisionConfig::dart_full_quant();
        let wd = Workload::paper_reference(ModelArch::llada_8b(), CacheMode::Dual);
        let wm = Workload::paper_reference(ModelArch::llada_moe_7b(), CacheMode::Dual);
        let sim = AnalyticalSim::new(HwConfig::dart_default(), p);
        assert!(sim.run(&wm).tps > 2.0 * sim.run(&wd).tps);
    }

    #[test]
    fn scheduled_run_bills_realized_steps() {
        let w = Workload::paper_reference(ModelArch::llada_8b(),
                                          CacheMode::Dual);
        let sim = AnalyticalSim::new(HwConfig::dart_default(),
                                     PrecisionConfig::dart_full_quant());
        // at the configured cap the scheduled path is bit-identical
        let full = sim.run(&w);
        let at_cap = sim.run_scheduled(&w, w.steps_per_block as f64);
        assert_eq!(full.total_s.to_bits(), at_cap.total_s.to_bits());
        assert_eq!(full.sampling.seconds.to_bits(),
                   at_cap.sampling.seconds.to_bits());
        // fewer realized steps cost strictly less, monotonically
        let half = sim.run_scheduled(&w, 8.0);
        let quarter = sim.run_scheduled(&w, 4.0);
        assert!(half.total_s < full.total_s);
        assert!(quarter.total_s < half.total_s);
        // fractional expectations land between their neighbors
        let mid = sim.run_scheduled(&w, 8.5);
        assert!(mid.total_s > half.total_s && mid.total_s < full.total_s);
        // clamped: below one step bills one step, above the cap bills
        // the cap
        let floor = sim.run_scheduled(&w, 0.2);
        let one = sim.run_scheduled(&w, 1.0);
        assert_eq!(floor.total_s.to_bits(), one.total_s.to_bits());
        let over = sim.run_scheduled(&w, 99.0);
        assert_eq!(over.total_s.to_bits(), full.total_s.to_bits());
    }

    #[test]
    fn cached_run_off_plan_is_bit_identical_to_scheduled() {
        use crate::cache::CachePlan;
        let sim = AnalyticalSim::new(HwConfig::dart_default(),
                                     PrecisionConfig::dart_full_quant());
        for cache in CacheMode::ALL {
            let w = Workload::paper_reference(ModelArch::llada_8b(), cache);
            for steps in [w.steps_per_block as f64, 9.25, 1.0] {
                let base = sim.run_scheduled(&w, steps);
                let off = sim.run_cached(&w, steps, &CachePlan::off());
                assert_eq!(base.total_s.to_bits(), off.total_s.to_bits(),
                           "{cache:?} steps {steps}");
                assert_eq!(base.model.seconds.to_bits(),
                           off.model.seconds.to_bits());
                assert_eq!(base.sampling.seconds.to_bits(),
                           off.sampling.seconds.to_bits());
                assert_eq!(base.model.hbm_bytes.to_bits(),
                           off.model.hbm_bytes.to_bits());
                assert_eq!(base.energy.total_j.to_bits(),
                           off.energy.total_j.to_bits());
            }
        }
    }

    #[test]
    fn cached_run_bills_less_as_reuse_grows() {
        use crate::cache::{expected_plan, CachePolicySpec};
        let w = Workload::paper_reference(ModelArch::llada_8b(),
                                          CacheMode::Dual);
        let sim = AnalyticalSim::new(HwConfig::dart_default(),
                                     PrecisionConfig::dart_full_quant());
        let steps = w.steps_per_block as f64;
        let base = sim.run_cached(&w, steps, &crate::cache::CachePlan::off());
        let plan = |p, r| expected_plan(
            &CachePolicySpec::Interval { prompt_every: p,
                                         response_every: r },
            w.block_len as usize, w.steps_per_block as usize,
            w.n_blocks() as usize);
        let mild = sim.run_cached(&w, steps, &plan(2, 2));
        let deep = sim.run_cached(&w, steps, &plan(4, 4));
        assert!(mild.total_s < base.total_s,
                "mild {} base {}", mild.total_s, base.total_s);
        assert!(deep.total_s < mild.total_s,
                "deep {} mild {}", deep.total_s, mild.total_s);
        // sampling is never cached: bit-identical across all arms
        assert_eq!(base.sampling.seconds.to_bits(),
                   deep.sampling.seconds.to_bits());
        // the adaptive expectation also prices below the off arm
        let ad = sim.run_cached(&w, steps, &expected_plan(
            &CachePolicySpec::adaptive_default(), w.block_len as usize,
            w.steps_per_block as usize, w.n_blocks() as usize));
        assert!(ad.total_s < base.total_s,
                "adaptive {} base {}", ad.total_s, base.total_s);
    }

    #[test]
    fn windowed_run_full_is_bit_identical_to_cached() {
        use crate::cache::CachePlan;
        use crate::window::WindowPolicySpec;
        let sim = AnalyticalSim::new(HwConfig::dart_default(),
                                     PrecisionConfig::dart_full_quant());
        for cache in CacheMode::ALL {
            let w = Workload::paper_reference(ModelArch::llada_8b(), cache);
            for steps in [w.steps_per_block as f64, 9.25, 1.0] {
                let base = sim.run_cached(&w, steps, &CachePlan::off());
                let full = sim.run_windowed(&w, steps, &CachePlan::off(),
                                            &WindowPolicySpec::Full);
                assert_eq!(base.total_s.to_bits(), full.total_s.to_bits(),
                           "{cache:?} steps {steps}");
                assert_eq!(base.model.seconds.to_bits(),
                           full.model.seconds.to_bits());
                assert_eq!(base.model.hbm_bytes.to_bits(),
                           full.model.hbm_bytes.to_bits());
                assert_eq!(base.sampling.seconds.to_bits(),
                           full.sampling.seconds.to_bits());
                assert_eq!(base.energy.total_j.to_bits(),
                           full.energy.total_j.to_bits());
            }
        }
    }

    #[test]
    fn windowed_run_bills_less_on_long_suffixes() {
        use crate::cache::CachePlan;
        use crate::window::WindowPolicySpec;
        let mut w = Workload::paper_reference(ModelArch::llada_8b(),
                                              CacheMode::Dual);
        // long-form shape: 4K prompt, 8K generation
        w.prompt_len = 4096;
        w.gen_len = 8192;
        let sim = AnalyticalSim::new(HwConfig::dart_default(),
                                     PrecisionConfig::dart_full_quant());
        let steps = w.steps_per_block as f64;
        let full = sim.run_windowed(&w, steps, &CachePlan::off(),
                                    &WindowPolicySpec::Full);
        let slide = sim.run_windowed(&w, steps, &CachePlan::off(),
                                     &WindowPolicySpec::sliding_default());
        let decay = sim.run_windowed(&w, steps, &CachePlan::off(),
                                     &WindowPolicySpec::decay_default());
        assert!(slide.total_s < full.total_s,
                "sliding {} full {}", slide.total_s, full.total_s);
        assert!(decay.total_s < slide.total_s,
                "decay {} sliding {}", decay.total_s, slide.total_s);
        // sampling over the active block is never windowed
        assert_eq!(full.sampling.seconds.to_bits(),
                   decay.sampling.seconds.to_bits());
        // windowing composes with the feature cache: both savings stack
        let plan = crate::cache::expected_plan(
            &crate::cache::CachePolicySpec::adaptive_default(),
            w.block_len as usize, w.steps_per_block as usize,
            w.n_blocks() as usize);
        let both = sim.run_windowed(&w, steps, &plan,
                                    &WindowPolicySpec::decay_default());
        assert!(both.total_s < decay.total_s,
                "cache+window {} window-only {}", both.total_s,
                decay.total_s);
    }

    #[test]
    fn run_report_records_phase_spans_and_counters() {
        let r = dart(CacheMode::Dual);
        let mut rec = crate::obs::Recorder::enabled(9);
        let end = r.record(&mut rec, 0.0);
        assert!((end - r.total_s).abs() < 1e-12);
        assert_eq!(rec.spans().len(), 2);
        assert_eq!(rec.spans()[0].name, "model");
        assert_eq!(rec.spans()[1].name, "sampling");
        // phase spans tile the run: model ends where sampling begins
        assert_eq!(rec.spans()[0].end_vt.to_bits(),
                   rec.spans()[1].begin_vt.to_bits());
        assert_eq!(rec.counter("sim.sampling.hbm_bytes"),
                   r.sampling.hbm_bytes);
        assert_eq!(rec.counter("sim.model.macs"), r.model.macs);
        // chaining: a second run starts where the first ended
        let end2 = r.record(&mut rec, end);
        assert!((end2 - 2.0 * r.total_s).abs() < 1e-9);
        assert_eq!(rec.counter("sim.model.hbm_bytes"),
                   2.0 * r.model.hbm_bytes);
    }

    #[test]
    fn energy_in_npu_regime() {
        let r = dart(CacheMode::Prefix);
        assert!(r.energy.avg_w > 15.0 && r.energy.avg_w < 250.0,
                "{} W", r.energy.avg_w);
    }
}
