//! Transaction-level cycle-accurate simulator (paper §4.2).
//!
//! Executes DART compiler-generated programs with **functional
//! numerics** (real data in the modeled SRAM domains, cross-checked
//! against the golden models and PyTorch-equivalent oracles) and
//! **transaction-level timing**: in-order issue, stall-on-dependency via
//! a register + SRAM-interval scoreboard, per-unit occupancy, background
//! HBM prefetch overlap through the Ramulator-style [`crate::hbm`]
//! model.
//!
//! Timing fidelity is the paper's: per-instruction latencies come from
//! the RTL-calibrated [`super::latency::LatencyLib`]; inter-stage
//! pipeline fill/drain is *not* modeled here (that is [`super::rtl`]'s
//! job), which is exactly the documented source of Table 3's
//! compound-sequence deltas.

use crate::config::HwConfig;
use crate::hbm::{Fidelity, HbmModel};
use crate::isa::{Instr, Program, Unit};
use crate::mem::{Domain, SramState};
use crate::quant;
use crate::sim::latency::LatencyLib;

/// Simulation outcome.
#[derive(Clone, Debug, Default)]
pub struct SimReport {
    pub cycles: u64,
    pub instrs: u64,
    pub stall_cycles: u64,
    pub hbm_bytes: u64,
    pub unit_busy: [(u64, &'static str); 4],
    pub hbm_busy_cycles: u64,
}

impl SimReport {
    /// Effective HBM bandwidth achieved over the run.
    pub fn hbm_bw(&self, clock_hz: f64) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.hbm_bytes as f64 / (self.cycles as f64 / clock_hz)
    }

    /// Emit the per-instruction-class cycle attribution into an `obs`
    /// recorder: one `cycle.run` span over the simulated time axis
    /// (cycles at `clock_hz`) plus `cycle.busy.*` counters per issue
    /// unit (matrix / vector / scalar / hbm), stall cycles, and HBM
    /// traffic — deterministic for a fixed program, so traced cycle
    /// runs summarize byte-identically.
    pub fn record(&self, rec: &mut crate::obs::Recorder, clock_hz: f64) {
        let total_s = self.cycles as f64 / clock_hz.max(1.0);
        rec.span_closed("cycle", "run", 0.0, total_s);
        rec.count("cycle.instrs", self.instrs as f64);
        rec.count("cycle.stall_cycles", self.stall_cycles as f64);
        rec.count("cycle.hbm_bytes", self.hbm_bytes as f64);
        for (busy, name) in &self.unit_busy {
            // counter names must be 'static: map the unit label
            let key: &'static str = match *name {
                "matrix" => "cycle.busy.matrix",
                "vector" => "cycle.busy.vector",
                "scalar" => "cycle.busy.scalar",
                _ => "cycle.busy.hbm",
            };
            rec.count(key, *busy as f64);
        }
    }
}

/// Outstanding write (scoreboard entry): resource + finish cycle.
#[derive(Clone, Debug)]
enum Write {
    Sram(Domain, u32, u32, u64),
    FpReg(u8, u64),
    GpReg(u8, u64),
}

pub struct CycleSim {
    pub hw: HwConfig,
    pub lat: LatencyLib,
    pub sram: SramState,
    pub fp_regs: [f32; crate::isa::NUM_FP_REGS],
    pub gp_regs: [i32; crate::isa::NUM_GP_REGS],
    /// functional HBM contents (f32 elements; ints are bit-cast)
    pub hbm_data: Vec<f32>,
    hbm: HbmModel,
    /// RTL-reference mode: add pipeline fill/drain per op (Table 3)
    pub rtl_fills: bool,
    writes: Vec<Write>,
    unit_free: [u64; 4],
    unit_busy: [u64; 4],
    now: u64,
    stalls: u64,
    hbm_bytes: u64,
    hbm_ns_base: f64,
}

fn unit_idx(u: Unit) -> usize {
    match u {
        Unit::Matrix => 0,
        Unit::Vector => 1,
        Unit::Scalar => 2,
        Unit::Hbm => 3,
        Unit::Control => 2, // control shares the scalar sequencer
    }
}

impl CycleSim {
    pub fn new(hw: HwConfig, hbm_elements: usize) -> Self {
        let lat = LatencyLib::new(hw.clone());
        let sram = SramState::new(&hw);
        let hbm = HbmModel::new(hw.hbm, Fidelity::Ideal);
        CycleSim {
            hw,
            lat,
            sram,
            fp_regs: [0.0; crate::isa::NUM_FP_REGS],
            gp_regs: [0; crate::isa::NUM_GP_REGS],
            hbm_data: vec![0.0; hbm_elements],
            hbm,
            rtl_fills: false,
            writes: Vec::new(),
            unit_free: [0; 4],
            unit_busy: [0; 4],
            now: 0,
            stalls: 0,
            hbm_bytes: 0,
            hbm_ns_base: 0.0,
        }
    }

    /// Load int data into functional HBM (bit-cast to the f32 backing).
    pub fn hbm_store_i32(&mut self, addr: usize, data: &[i32]) {
        for (i, &v) in data.iter().enumerate() {
            self.hbm_data[addr + i] = f32::from_bits(v as u32);
        }
    }

    pub fn hbm_store_f32(&mut self, addr: usize, data: &[f32]) {
        self.hbm_data[addr..addr + data.len()].copy_from_slice(data);
    }

    // ---- scoreboard ------------------------------------------------------

    fn read_ready(&self, domain: Domain, addr: u32, len: u32) -> u64 {
        self.writes.iter().filter_map(|w| match w {
            Write::Sram(d, a, l, f)
                if *d == domain && *a < addr + len && addr < *a + *l => Some(*f),
            _ => None,
        }).max().unwrap_or(0)
    }

    fn fp_ready(&self, reg: u8) -> u64 {
        self.writes.iter().filter_map(|w| match w {
            Write::FpReg(r, f) if *r == reg => Some(*f),
            _ => None,
        }).max().unwrap_or(0)
    }

    fn gp_ready(&self, reg: u8) -> u64 {
        self.writes.iter().filter_map(|w| match w {
            Write::GpReg(r, f) if *r == reg => Some(*f),
            _ => None,
        }).max().unwrap_or(0)
    }

    fn retire(&mut self) {
        let now = self.now;
        self.writes.retain(|w| match w {
            Write::Sram(_, _, _, f) | Write::FpReg(_, f) | Write::GpReg(_, f) => *f > now,
        });
    }

    /// Earliest issue cycle for `ins` given dependencies (RAW + WAW).
    fn deps_ready(&self, ins: &Instr) -> u64 {
        use Instr::*;
        let v = Domain::Vector;
        let m = Domain::Matrix;
        let i = Domain::Int;
        let f = Domain::Fp;
        match ins {
            MGemm { act, wgt, m: mm, k, n, dst, .. } => self
                .read_ready(v, *act, mm * k)
                .max(self.read_ready(m, *wgt, k * n))
                .max(self.read_ready(v, *dst, mm * n)),
            MSum { src, parts, len, dst } => self
                .read_ready(v, *src, parts * len)
                .max(self.read_ready(v, *dst, *len)),
            VAddVV { a, b, len, dst } | VSubVV { a, b, len, dst }
            | VMulVV { a, b, len, dst } => self
                .read_ready(v, *a, *len)
                .max(self.read_ready(v, *b, *len))
                .max(self.read_ready(v, *dst, *len)),
            VExpV { src, len, dst } | VRecipV { src, len, dst }
            | VQuantMx { src, len, dst, .. } => self
                .read_ready(v, *src, *len)
                .max(self.read_ready(v, *dst, *len)),
            VAddVS { a, s, len, dst } | VMulVS { a, s, len, dst } => self
                .read_ready(v, *a, *len)
                .max(self.fp_ready(*s))
                .max(self.read_ready(v, *dst, *len)),
            VRedMax { src, len, dst } | VRedSum { src, len, dst } => self
                .read_ready(v, *src, *len)
                .max(self.fp_ready(*dst)),
            VRedMaxIdx { src, len, dst_val, dst_idx, .. } => self
                .read_ready(v, *src, *len)
                .max(self.fp_ready(*dst_val))
                .max(self.gp_ready(*dst_idx)),
            VTopkMask { conf, mask, k, len, dst } => self
                .read_ready(v, *conf, *len)
                .max(self.read_ready(i, *mask, *len))
                .max(self.gp_ready(*k))
                .max(self.read_ready(i, *dst, *len)),
            VSelectInt { mask, a, b, len, dst } => self
                .read_ready(i, *mask, *len)
                .max(self.read_ready(i, *a, *len))
                .max(self.read_ready(i, *b, *len))
                .max(self.read_ready(i, *dst, *len)),
            VEqIs { src, len, dst, .. } => self
                .read_ready(i, *src, *len)
                .max(self.read_ready(i, *dst, *len)),
            SStFp { src, addr } => self.fp_ready(*src).max(self.read_ready(f, *addr, 1)),
            SLdFp { dst, addr } => self.read_ready(f, *addr, 1).max(self.fp_ready(*dst)),
            SStInt { src, addr } => self.gp_ready(*src).max(self.read_ready(i, *addr, 1)),
            SLdInt { dst, addr } => self.read_ready(i, *addr, 1).max(self.gp_ready(*dst)),
            SMapVFp { src, len, dst } => self
                .read_ready(f, *src, *len)
                .max(self.read_ready(v, *dst, *len)),
            SRecip { dst, src } => self.fp_ready(*src).max(self.fp_ready(*dst)),
            SAddF { dst, a, b } | SMulF { dst, a, b } => self
                .fp_ready(*a).max(self.fp_ready(*b)).max(self.fp_ready(*dst)),
            SMovI { dst, .. } => self.gp_ready(*dst),
            SMovF { dst, .. } => self.fp_ready(*dst),
            SAddI { dst, a, .. } => self.gp_ready(*a).max(self.gp_ready(*dst)),
            SSoftmax { v: addr, len } | SLayerNorm { v: addr, len }
            | SSilu { v: addr, len } | SGelu { v: addr, len } =>
                self.read_ready(v, *addr, *len),
            HPrefetchV { dst, len, .. } => self.read_ready(v, *dst, *len),
            HPrefetchM { dst, len, .. } => self.read_ready(m, *dst, *len),
            HStore { src, len, .. } => self.read_ready(v, *src, *len),
            CLoop { .. } | CEndLoop | CBarrier | CHalt => 0,
        }
    }

    // ---- functional execution --------------------------------------------

    fn exec(&mut self, ins: &Instr, finish: u64) {
        use Instr::*;
        let wv = |s: &mut Self, a: u32, l: u32, f: u64| {
            s.writes.push(Write::Sram(Domain::Vector, a, l, f))
        };
        match ins {
            MGemm { dst, act, wgt, m, k, n, transpose } => {
                let (m, k, n) = (*m as usize, *k as usize, *n as usize);
                let a = self.sram.v(*act, (m * k) as u32).to_vec();
                let w = self.sram.m(*wgt, (k * n) as u32).to_vec();
                let out = self.sram.v_mut(*dst, (m * n) as u32);
                for mi in 0..m {
                    for ni in 0..n {
                        let mut acc = 0f32;
                        for ki in 0..k {
                            let wv = if *transpose { w[ni * k + ki] } else { w[ki * n + ni] };
                            acc += a[mi * k + ki] * wv;
                        }
                        out[mi * n + ni] = acc;
                    }
                }
                wv(self, *dst, (m * n) as u32, finish);
            }
            MSum { dst, src, parts, len } => {
                let mut acc = vec![0f32; *len as usize];
                for p in 0..*parts {
                    let part = self.sram.v(src + p * len, *len);
                    for (a, &x) in acc.iter_mut().zip(part) {
                        *a += x;
                    }
                }
                self.sram.v_mut(*dst, *len).copy_from_slice(&acc);
                wv(self, *dst, *len, finish);
            }
            VAddVV { dst, a, b, len } | VSubVV { dst, a, b, len }
            | VMulVV { dst, a, b, len } => {
                let av = self.sram.v(*a, *len).to_vec();
                let bv = self.sram.v(*b, *len).to_vec();
                let out = self.sram.v_mut(*dst, *len);
                for j in 0..*len as usize {
                    out[j] = match ins {
                        VAddVV { .. } => av[j] + bv[j],
                        VSubVV { .. } => av[j] - bv[j],
                        _ => av[j] * bv[j],
                    };
                }
                wv(self, *dst, *len, finish);
            }
            VExpV { dst, src, len } => {
                // hot path in sampling programs: avoid the temp copy
                // (src may alias dst — the paper's in-place V_EXP_V)
                if dst == src {
                    for v in self.sram.v_mut(*dst, *len) {
                        *v = v.exp();
                    }
                } else {
                    let s = self.sram.v(*src, *len).to_vec();
                    let out = self.sram.v_mut(*dst, *len);
                    for j in 0..*len as usize {
                        out[j] = s[j].exp();
                    }
                }
                wv(self, *dst, *len, finish);
            }
            VRecipV { dst, src, len } => {
                let s = self.sram.v(*src, *len).to_vec();
                let out = self.sram.v_mut(*dst, *len);
                for j in 0..*len as usize {
                    out[j] = 1.0 / s[j];
                }
                wv(self, *dst, *len, finish);
            }
            VAddVS { dst, a, s, len } => {
                let sv = self.fp_regs[*s as usize];
                if dst == a {
                    for v in self.sram.v_mut(*dst, *len) {
                        *v += sv;
                    }
                } else {
                    let av = self.sram.v(*a, *len).to_vec();
                    let out = self.sram.v_mut(*dst, *len);
                    for j in 0..*len as usize {
                        out[j] = av[j] + sv;
                    }
                }
                wv(self, *dst, *len, finish);
            }
            VMulVS { dst, a, s, len } => {
                let sv = self.fp_regs[*s as usize];
                let av = self.sram.v(*a, *len).to_vec();
                let out = self.sram.v_mut(*dst, *len);
                for j in 0..*len as usize {
                    out[j] = av[j] * sv;
                }
                wv(self, *dst, *len, finish);
            }
            VRedMax { dst, src, len } => {
                let m = self.sram.v(*src, *len).iter().cloned()
                    .fold(f32::NEG_INFINITY, f32::max);
                self.fp_regs[*dst as usize] = self.fp_regs[*dst as usize].max(m);
                self.writes.push(Write::FpReg(*dst, finish));
            }
            VRedSum { dst, src, len } => {
                let s: f32 = self.sram.v(*src, *len).iter().sum();
                self.fp_regs[*dst as usize] += s;
                self.writes.push(Write::FpReg(*dst, finish));
            }
            VRedMaxIdx { dst_val, dst_idx, src, len, idx_base } => {
                // accumulating fused max-with-index: updates (val, idx)
                // registers only on strict improvement, so chunk streams
                // fold into a running global argmax
                let data = self.sram.v(*src, *len);
                let mut cm = f32::NEG_INFINITY;
                let mut ci = 0u32;
                for (j, &val) in data.iter().enumerate() {
                    if val > cm {
                        cm = val;
                        ci = j as u32;
                    }
                }
                if cm > self.fp_regs[*dst_val as usize] {
                    self.fp_regs[*dst_val as usize] = cm;
                    self.gp_regs[*dst_idx as usize] = (idx_base + ci) as i32;
                }
                self.writes.push(Write::FpReg(*dst_val, finish));
                self.writes.push(Write::GpReg(*dst_idx, finish));
            }
            VTopkMask { dst, conf, mask, k, len } => {
                let confs = self.sram.v(*conf, *len).to_vec();
                let masks = self.sram.i(*mask, *len).to_vec();
                let kk = self.gp_regs[*k as usize].max(0) as usize;
                let sel = crate::sampling::topk_mask(&confs, &masks, kk);
                let out = self.sram.i_mut(*dst, *len);
                for (o, s) in out.iter_mut().zip(&sel) {
                    *o = *s as i32;
                }
                self.writes.push(Write::Sram(Domain::Int, *dst, *len, finish));
            }
            VSelectInt { dst, mask, a, b, len } => {
                let m = self.sram.i(*mask, *len).to_vec();
                let av = self.sram.i(*a, *len).to_vec();
                let bv = self.sram.i(*b, *len).to_vec();
                let out = self.sram.i_mut(*dst, *len);
                for j in 0..*len as usize {
                    out[j] = if m[j] != 0 { av[j] } else { bv[j] };
                }
                self.writes.push(Write::Sram(Domain::Int, *dst, *len, finish));
            }
            VEqIs { dst, src, imm, len } => {
                let s = self.sram.i(*src, *len).to_vec();
                let out = self.sram.i_mut(*dst, *len);
                for j in 0..*len as usize {
                    out[j] = (s[j] == *imm) as i32;
                }
                self.writes.push(Write::Sram(Domain::Int, *dst, *len, finish));
            }
            VQuantMx { dst, src, len, bits } => {
                let fmt = match bits {
                    4 => quant::MxFormat::MxInt4,
                    6 => quant::MxFormat::MxInt6,
                    _ => quant::MxFormat::MxInt8,
                };
                let s = self.sram.v(*src, *len).to_vec();
                let q = quant::fake_quant(&s, fmt);
                self.sram.v_mut(*dst, *len).copy_from_slice(&q);
                wv(self, *dst, *len, finish);
            }
            SStFp { src, addr } => {
                self.sram.fp[*addr as usize] = self.fp_regs[*src as usize];
                self.writes.push(Write::Sram(Domain::Fp, *addr, 1, finish));
            }
            SLdFp { dst, addr } => {
                self.fp_regs[*dst as usize] = self.sram.fp[*addr as usize];
                self.writes.push(Write::FpReg(*dst, finish));
            }
            SStInt { src, addr } => {
                self.sram.int[*addr as usize] = self.gp_regs[*src as usize];
                self.writes.push(Write::Sram(Domain::Int, *addr, 1, finish));
            }
            SLdInt { dst, addr } => {
                self.gp_regs[*dst as usize] = self.sram.int[*addr as usize];
                self.writes.push(Write::GpReg(*dst, finish));
            }
            SMapVFp { dst, src, len } => {
                let vals: Vec<f32> =
                    self.sram.fp[*src as usize..(*src + *len) as usize].to_vec();
                self.sram.v_mut(*dst, *len).copy_from_slice(&vals);
                wv(self, *dst, *len, finish);
            }
            SRecip { dst, src } => {
                self.fp_regs[*dst as usize] = 1.0 / self.fp_regs[*src as usize];
                self.writes.push(Write::FpReg(*dst, finish));
            }
            SAddF { dst, a, b } => {
                self.fp_regs[*dst as usize] =
                    self.fp_regs[*a as usize] + self.fp_regs[*b as usize];
                self.writes.push(Write::FpReg(*dst, finish));
            }
            SMulF { dst, a, b } => {
                self.fp_regs[*dst as usize] =
                    self.fp_regs[*a as usize] * self.fp_regs[*b as usize];
                self.writes.push(Write::FpReg(*dst, finish));
            }
            SMovI { dst, imm } => {
                self.gp_regs[*dst as usize] = *imm;
                self.writes.push(Write::GpReg(*dst, finish));
            }
            SMovF { dst, imm } => {
                self.fp_regs[*dst as usize] = *imm;
                self.writes.push(Write::FpReg(*dst, finish));
            }
            SAddI { dst, a, imm } => {
                self.gp_regs[*dst as usize] = self.gp_regs[*a as usize] + imm;
                self.writes.push(Write::GpReg(*dst, finish));
            }
            SSoftmax { v, len } => {
                let data = self.sram.v(*v, *len).to_vec();
                let m = data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let exps: Vec<f32> = data.iter().map(|&x| (x - m).exp()).collect();
                let s: f32 = exps.iter().sum();
                let out = self.sram.v_mut(*v, *len);
                for (o, e) in out.iter_mut().zip(&exps) {
                    *o = e / s;
                }
                wv(self, *v, *len, finish);
            }
            SLayerNorm { v, len } => {
                let data = self.sram.v(*v, *len).to_vec();
                let n = *len as f32;
                let mean: f32 = data.iter().sum::<f32>() / n;
                let var: f32 = data.iter().map(|&x| (x - mean) * (x - mean))
                    .sum::<f32>() / n;
                let inv = 1.0 / (var + 1e-5).sqrt();
                let out = self.sram.v_mut(*v, *len);
                for (o, &x) in out.iter_mut().zip(&data) {
                    *o = (x - mean) * inv;
                }
                wv(self, *v, *len, finish);
            }
            SSilu { v, len } => {
                let data = self.sram.v(*v, *len).to_vec();
                let out = self.sram.v_mut(*v, *len);
                for (o, &x) in out.iter_mut().zip(&data) {
                    *o = x / (1.0 + (-x).exp());
                }
                wv(self, *v, *len, finish);
            }
            SGelu { v, len } => {
                let data = self.sram.v(*v, *len).to_vec();
                let out = self.sram.v_mut(*v, *len);
                for (o, &x) in out.iter_mut().zip(&data) {
                    let c = (2.0f32 / std::f32::consts::PI).sqrt();
                    *o = 0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh());
                }
                wv(self, *v, *len, finish);
            }
            HPrefetchV { hbm, dst, len } => {
                let src = *hbm as usize;
                let data = self.hbm_data[src..src + *len as usize].to_vec();
                self.sram.v_mut(*dst, *len).copy_from_slice(&data);
                wv(self, *dst, *len, finish);
            }
            HPrefetchM { hbm, dst, len } => {
                let src = *hbm as usize;
                let data = self.hbm_data[src..src + *len as usize].to_vec();
                self.sram.m_mut(*dst, *len).copy_from_slice(&data);
                self.writes.push(Write::Sram(Domain::Matrix, *dst, *len, finish));
            }
            HStore { src, hbm, len } => {
                let data = self.sram.v(*src, *len).to_vec();
                let dst = *hbm as usize;
                self.hbm_data[dst..dst + *len as usize].copy_from_slice(&data);
                // HBM contents guarded by the barrier mechanism
            }
            CLoop { .. } | CEndLoop | CBarrier | CHalt => {}
        }
    }

    // ---- main loop ---------------------------------------------------------

    /// Run a program to completion; returns the timing report.
    pub fn run(&mut self, prog: &Program) -> SimReport {
        prog.validate().expect("invalid program");
        let clock_ghz = self.hw.clock_hz / 1e9;
        let mut instrs = 0u64;
        // loop stack: (body_start_pc, remaining_trips)
        let mut stack: Vec<(usize, u32)> = Vec::new();
        let mut pc = 0usize;
        while pc < prog.instrs.len() {
            let ins = &prog.instrs[pc];
            instrs += 1;
            match ins {
                Instr::CLoop { count } => {
                    stack.push((pc + 1, *count - 1));
                    pc += 1;
                    continue;
                }
                Instr::CEndLoop => {
                    let (start, rem) = stack.pop().expect("unbalanced loop");
                    if rem > 0 {
                        stack.push((start, rem - 1));
                        pc = start;
                    } else {
                        pc += 1;
                    }
                    continue;
                }
                Instr::CBarrier => {
                    // wait for all outstanding writes + HBM transfers
                    let drain = self.writes.iter().map(|w| match w {
                        Write::Sram(_, _, _, f) | Write::FpReg(_, f)
                        | Write::GpReg(_, f) => *f,
                    }).max().unwrap_or(0);
                    self.now = self.now.max(drain).max(
                        (self.hbm.now_ns * clock_ghz) as u64);
                    self.retire();
                    pc += 1;
                    continue;
                }
                Instr::CHalt => break,
                _ => {}
            }

            let unit = unit_idx(ins.unit());
            let ready = self.deps_ready(ins).max(self.unit_free[unit]).max(self.now);
            self.stalls += ready - self.now;
            // in-order issue: program order advances time
            self.now = ready;
            self.retire();

            let finish = if unit == 3 {
                // HBM transaction: latency from the DRAM model
                let (hbm_addr, len, write) = match ins {
                    Instr::HPrefetchV { hbm, len, .. }
                    | Instr::HPrefetchM { hbm, len, .. } => (*hbm, *len, false),
                    Instr::HStore { hbm, len, .. } => (*hbm, *len, true),
                    _ => unreachable!(),
                };
                let bytes = len as u64 * 4;
                self.hbm_bytes += bytes;
                let start_ns = self.now as f64 / clock_ghz;
                let fin_ns = self.hbm.transact(hbm_addr * 4, bytes, write,
                                               start_ns.max(self.hbm_ns_base));
                self.hbm_ns_base = fin_ns;
                (fin_ns * clock_ghz).ceil() as u64
            } else {
                let mut cycles = self.lat.instr(ins);
                if self.rtl_fills {
                    cycles += match ins {
                        Instr::MGemm { .. } | Instr::MSum { .. } =>
                            self.lat.p.rtl_gemm_fill,
                        Instr::SSoftmax { .. } | Instr::SLayerNorm { .. } =>
                            self.lat.p.rtl_drain,
                        _ => 0,
                    };
                }
                self.now + cycles
            };

            // the issuing unit is busy until `finish` except the HBM
            // engine, which queues in the background (prefetch overlap)
            if unit == 3 {
                self.unit_free[unit] = self.now + 1;
                self.unit_busy[unit] += finish.saturating_sub(self.now);
            } else {
                self.unit_free[unit] = finish;
                self.unit_busy[unit] += finish - self.now;
            }
            self.exec(ins, finish);
            pc += 1;
        }
        // final drain
        let drain = self.writes.iter().map(|w| match w {
            Write::Sram(_, _, _, f) | Write::FpReg(_, f) | Write::GpReg(_, f) => *f,
        }).max().unwrap_or(0);
        let hbm_end = (self.hbm.now_ns * clock_ghz) as u64;
        self.now = self.now.max(drain).max(hbm_end);

        SimReport {
            cycles: self.now,
            instrs,
            stall_cycles: self.stalls,
            hbm_bytes: self.hbm_bytes,
            unit_busy: [
                (self.unit_busy[0], "matrix"),
                (self.unit_busy[1], "vector"),
                (self.unit_busy[2], "scalar"),
                (self.unit_busy[3], "hbm"),
            ],
            hbm_busy_cycles: self.unit_busy[3],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwConfig;
    use crate::isa::{Instr::*, ProgramBuilder};

    fn sim() -> CycleSim {
        CycleSim::new(HwConfig::validation_point(), 1 << 20)
    }

    #[test]
    fn vector_add_functional_and_timed() {
        let mut s = sim();
        s.sram.v_mut(0, 4).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        s.sram.v_mut(4, 4).copy_from_slice(&[10.0, 20.0, 30.0, 40.0]);
        let mut b = ProgramBuilder::new();
        b.push(VAddVV { dst: 8, a: 0, b: 4, len: 4 });
        let r = s.run(&b.finish());
        assert_eq!(s.sram.v(8, 4), &[11.0, 22.0, 33.0, 44.0]);
        assert_eq!(r.cycles, 7); // 6 fill + 1 chunk
    }

    #[test]
    fn sim_report_records_unit_attribution() {
        let mut s = sim();
        s.sram.v_mut(0, 8).copy_from_slice(&[1.0; 8]);
        let mut b = ProgramBuilder::new();
        b.push(VAddVV { dst: 8, a: 0, b: 0, len: 8 });
        let r = s.run(&b.finish());
        let clock = s.hw.clock_hz;
        let mut rec = crate::obs::Recorder::enabled(1);
        r.record(&mut rec, clock);
        assert_eq!(rec.spans().len(), 1);
        assert_eq!(rec.spans()[0].name, "run");
        assert!((rec.spans()[0].end_vt - r.cycles as f64 / clock).abs()
                < 1e-18);
        assert_eq!(rec.counter("cycle.busy.vector"),
                   r.unit_busy[1].0 as f64);
        assert_eq!(rec.counter("cycle.instrs"), r.instrs as f64);
        assert_eq!(rec.counter("cycle.busy.matrix"), 0.0);
    }

    #[test]
    fn raw_dependency_stalls() {
        let mut s = sim();
        s.sram.v_mut(0, 8).copy_from_slice(&[1.0; 8]);
        let mut b = ProgramBuilder::new();
        b.push(VAddVV { dst: 8, a: 0, b: 0, len: 8 });   // finish @7
        b.push(VMulVV { dst: 16, a: 8, b: 8, len: 8 });  // RAW on 8
        let r = s.run(&b.finish());
        // the second op can't start before cycle 7; unit also busy to 7
        assert_eq!(r.cycles, 14);
        assert_eq!(s.sram.v(16, 8)[0], 4.0);
    }

    #[test]
    fn independent_units_overlap() {
        let mut s = sim();
        s.sram.v_mut(0, 8).fill(1.0);
        s.sram.m_mut(0, 8).fill(1.0);
        let mut b = ProgramBuilder::new();
        // scalar op + vector op on disjoint data overlap in time
        b.push(VAddVV { dst: 16, a: 0, b: 0, len: 8 });
        b.push(SMovF { dst: 1, imm: 3.0 });
        let r = s.run(&b.finish());
        assert_eq!(r.cycles, 7); // scalar hid under vector
    }

    #[test]
    fn gemm_functional_matches_matmul() {
        let mut s = sim();
        // act [2x3] @ wgt [3x2]
        s.sram.v_mut(0, 6).copy_from_slice(&[1., 2., 3., 4., 5., 6.]);
        s.sram.m_mut(0, 6).copy_from_slice(&[7., 8., 9., 10., 11., 12.]);
        let mut b = ProgramBuilder::new();
        b.push(MGemm { dst: 16, act: 0, wgt: 0, m: 2, k: 3, n: 2,
                       transpose: false });
        s.run(&b.finish());
        // [[1,2,3],[4,5,6]] @ [[7,8],[9,10],[11,12]] = [[58,64],[139,154]]
        assert_eq!(s.sram.v(16, 4), &[58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn gemm_transpose() {
        let mut s = sim();
        s.sram.v_mut(0, 2).copy_from_slice(&[1., 2.]);
        // w stored [n=2, k=2] row-major, used transposed
        s.sram.m_mut(0, 4).copy_from_slice(&[1., 0., 0., 1.]);
        let mut b = ProgramBuilder::new();
        b.push(MGemm { dst: 8, act: 0, wgt: 0, m: 1, k: 2, n: 2,
                       transpose: true });
        s.run(&b.finish());
        assert_eq!(s.sram.v(8, 2), &[1.0, 2.0]);
    }

    #[test]
    fn loops_execute_functionally() {
        let mut s = sim();
        s.sram.v_mut(0, 4).fill(1.0);
        let mut b = ProgramBuilder::new();
        b.repeat(5, |b| {
            b.push(VAddVV { dst: 0, a: 0, b: 0, len: 4 }); // doubles
        });
        s.run(&b.finish());
        assert_eq!(s.sram.v(0, 1)[0], 32.0); // 2^5
    }

    #[test]
    fn red_max_idx_accumulates_across_chunks() {
        let mut s = sim();
        s.sram.v_mut(0, 8).copy_from_slice(&[1., 2., 9., 4., 5., 6., 7., 8.]);
        let mut b = ProgramBuilder::new();
        b.push(SMovF { dst: 0, imm: f32::NEG_INFINITY });
        b.push(SMovI { dst: 0, imm: 0 });
        b.push(VRedMaxIdx { dst_val: 0, dst_idx: 0, src: 0, len: 4, idx_base: 100 });
        b.push(VRedMaxIdx { dst_val: 0, dst_idx: 0, src: 4, len: 4, idx_base: 104 });
        s.run(&b.finish());
        assert_eq!(s.fp_regs[0], 9.0);
        assert_eq!(s.gp_regs[0], 102); // global index of the 9.0
    }

    #[test]
    fn hbm_prefetch_moves_data_and_takes_time() {
        let mut s = sim();
        s.hbm_store_f32(1000, &[5.0, 6.0, 7.0, 8.0]);
        let mut b = ProgramBuilder::new();
        b.push(HPrefetchV { hbm: 1000, dst: 0, len: 4 });
        b.barrier();
        b.push(VAddVV { dst: 8, a: 0, b: 0, len: 4 });
        let r = s.run(&b.finish());
        assert_eq!(s.sram.v(8, 4), &[10.0, 12.0, 14.0, 16.0]);
        assert!(r.hbm_bytes == 16);
        assert!(r.cycles > 7); // includes HBM latency
    }

    #[test]
    fn softmax_functional() {
        let mut s = sim();
        s.sram.v_mut(0, 4).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let mut b = ProgramBuilder::new();
        b.push(SSoftmax { v: 0, len: 4 });
        s.run(&b.finish());
        let out = s.sram.v(0, 4);
        let sum: f32 = out.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(out[3] > out[2] && out[2] > out[1]);
    }

    #[test]
    fn rtl_mode_adds_fill() {
        let run = |rtl: bool| {
            let mut s = sim();
            s.rtl_fills = rtl;
            s.sram.v_mut(0, 64).fill(1.0);
            s.sram.m_mut(0, 64 * 64).fill(0.5);
            let mut b = ProgramBuilder::new();
            b.push(MGemm { dst: 128, act: 0, wgt: 0, m: 1, k: 64, n: 64,
                           transpose: false });
            s.run(&b.finish()).cycles
        };
        let sim_c = run(false);
        let rtl_c = run(true);
        assert_eq!(sim_c, 80);
        assert_eq!(rtl_c, 86); // the Table 3 +6 pipeline-fill delta
    }
}
