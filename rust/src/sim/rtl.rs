//! RTL-reference pipeline model — the Verilator substitute (docs/ARCHITECTURE.md
//! substitution S2, paper §5.2).
//!
//! The paper validates its transaction-level simulator bottom-up against
//! Verilator RTL and attributes **all** compound-sequence error to
//! pipeline inter-stage costs the simulator does not model: a constant
//! ≈6-cycle first-tile pipeline-fill per matrix op and a ≈5-cycle drain
//! between a compound scalar op's reduction and elementwise stages.
//!
//! We reproduce that structure exactly: the RTL reference is the same
//! execution engine with those fill/drain constants enabled
//! (single-instruction latencies are shared — "exact by construction" —
//! so compound deltas isolate the pipeline overheads, giving Table 3's
//! −7%/−11.6%/−8.9% shape).

use crate::config::HwConfig;
use crate::isa::Program;
use crate::sim::cycle::{CycleSim, SimReport};

/// Run a program on the RTL-reference configuration.
pub fn run_rtl(hw: HwConfig, hbm_elements: usize, prog: &Program) -> SimReport {
    let mut sim = CycleSim::new(hw, hbm_elements);
    sim.rtl_fills = true;
    sim.run(prog)
}

/// Run the same program on both models; returns (rtl, sim, rel_error).
/// Negative error = simulator underestimates (the paper's sign).
pub fn cross_validate(hw: &HwConfig, hbm_elements: usize, prog: &Program)
                      -> (SimReport, SimReport, f64) {
    let rtl = run_rtl(hw.clone(), hbm_elements, prog);
    let mut s = CycleSim::new(hw.clone(), hbm_elements);
    let sim = s.run(prog);
    let err = sim.cycles as f64 / rtl.cycles as f64 - 1.0;
    (rtl, sim, err)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler;
    use crate::config::HwConfig;

    #[test]
    fn single_instructions_identical_by_construction() {
        // single vector instructions carry no fill constants in either
        // model — Table 3's "Sim ≡ RTL by construction"
        let hw = HwConfig::validation_point();
        let prog = crate::isa::asm::assemble(
            "V_EXP_V 0, 0, 8\nC_HALT\n").unwrap();
        let (rtl, sim, err) = cross_validate(&hw, 64, &prog);
        assert_eq!(rtl.cycles, sim.cycles);
        assert_eq!(err, 0.0);
    }

    #[test]
    fn gemm_compound_error_is_minus_seven_pct() {
        let hw = HwConfig::validation_point();
        let prog = compiler::gemm_program(1, 64, 64);
        let (rtl, sim, err) = cross_validate(&hw, 1 << 16, &prog);
        assert_eq!(sim.cycles, 80);
        assert_eq!(rtl.cycles, 86);
        assert!((err - (-0.0698)).abs() < 0.01, "err {err}");
    }

    #[test]
    fn error_shrinks_with_tile_count() {
        // the −6 is constant per op, so relative error diminishes at
        // larger tile counts (paper: "at larger tile counts the relative
        // impact diminishes further")
        let hw = HwConfig::validation_point();
        let small = cross_validate(&hw, 1 << 16, &compiler::gemm_program(1, 64, 64)).2;
        let large = cross_validate(&hw, 1 << 20, &compiler::gemm_program(4, 64, 256)).2;
        assert!(large.abs() < small.abs(), "small {small}, large {large}");
    }
}
