//! Feature-cache policies: who recomputes what, per block-step.
//!
//! [`CachePolicySpec`] is the copyable description the CLI flags, study
//! grids and topology configs carry; [`CachePlanner`] is the stateful
//! per-generation driver the engine steps through; [`CacheStats`] is the
//! deterministic accounting every consulted lookup lands in.
//!
//! The contract that licenses the engine integration
//! (`rust/tests/cache_equivalence.rs`): `Off` never consults the cache
//! and reproduces the pre-cache engine bit-exactly, and
//! `Interval { prompt_every: 1, response_every: 1 }` — refresh
//! everything at every opportunity — takes exactly the same actions as
//! `Off`, so the whole cached control path collapses to the baseline
//! when the refresh intervals are degenerate.

/// Per-step decision of the feature-cache planner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheAction {
    /// run the full warm forward (prompt + response features recomputed)
    Full,
    /// run the refine forward (response features recomputed, cached
    /// prompt/prefix features reused)
    Refresh,
    /// skip the forward entirely and reuse the cached block logits
    Reuse,
}

/// Copyable description of a cross-step feature-cache policy (the
/// dLLM-Cache model: prompt features refreshed at long intervals,
/// response features refreshed adaptively between denoising steps).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CachePolicySpec {
    /// no feature cache: bit-exact with the pre-cache engine (default)
    Off,
    /// fixed refresh intervals: full (prompt-refreshing) forward every
    /// `prompt_every`-th block, response features recomputed every
    /// `response_every`-th refine step; `{1, 1}` degenerates to `Off`
    Interval { prompt_every: usize, response_every: usize },
    /// adaptive refresh driven by a feature-drift proxy: recompute when
    /// the fraction of block tokens committed since the last refresh
    /// reaches `tau`, or `max_interval` steps have gone stale
    Adaptive { tau: f64, max_interval: usize },
}

impl Default for CachePolicySpec {
    fn default() -> Self {
        CachePolicySpec::Off
    }
}

impl CachePolicySpec {
    /// The default interval policy: prompt features every 4 blocks,
    /// response features every 4 refine steps.
    pub fn interval_default() -> Self {
        CachePolicySpec::Interval { prompt_every: 4, response_every: 4 }
    }

    /// The default adaptive policy: refresh at 35% committed drift or
    /// after 8 stale steps, whichever first.
    pub fn adaptive_default() -> Self {
        CachePolicySpec::Adaptive { tau: 0.35, max_interval: 8 }
    }

    /// Parse `off | interval[:P:R] | adaptive[:TAU:MAX]`
    /// (case-insensitive). Colon-separated so the combined `--cache`
    /// flag can stay comma-separated.
    pub fn parse(s: &str) -> Option<Self> {
        let lower = s.to_ascii_lowercase();
        let mut parts = lower.split(':');
        match parts.next()? {
            "off" => Some(CachePolicySpec::Off),
            "interval" => {
                let p = match parts.next() {
                    Some(v) => v.parse().ok().filter(|&p: &usize| p > 0)?,
                    None => 4,
                };
                let r = match parts.next() {
                    Some(v) => v.parse().ok().filter(|&r: &usize| r > 0)?,
                    None => 4,
                };
                Some(CachePolicySpec::Interval {
                    prompt_every: p,
                    response_every: r,
                })
            }
            "adaptive" => {
                let tau = match parts.next() {
                    Some(v) => v.parse().ok()
                        .filter(|t: &f64| t.is_finite() && *t > 0.0
                                && *t <= 1.0)?,
                    None => 0.35,
                };
                let max = match parts.next() {
                    Some(v) => v.parse().ok().filter(|&m: &usize| m > 0)?,
                    None => 8,
                };
                Some(CachePolicySpec::Adaptive { tau, max_interval: max })
            }
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CachePolicySpec::Off => "off",
            CachePolicySpec::Interval { .. } => "interval",
            CachePolicySpec::Adaptive { .. } => "adaptive",
        }
    }

    pub fn is_off(&self) -> bool {
        matches!(self, CachePolicySpec::Off)
    }

    /// Build the stateful per-generation planner.
    pub fn build(&self, block_len: usize) -> CachePlanner {
        CachePlanner::new(*self, block_len)
    }

    /// Expected hit rate of this policy at the given block geometry
    /// (the synthetic S10 pricing — see [`crate::cache::expected_plan`]).
    pub fn expected_hit_rate(&self, block_len: usize,
                             steps_per_block: usize, n_blocks: usize)
                             -> f64 {
        super::sim::expected_plan(self, block_len, steps_per_block,
                                  n_blocks)
            .hit_rate(steps_per_block as f64)
    }

    /// [`Self::expected_hit_rate`] at the canonical serving block count
    /// ([`REF_N_BLOCKS`]). The calibration profiler records this value
    /// on the curve and the cluster scheduler computes its serving hit
    /// rate through the same call, so a topology served under the
    /// policy it was profiled with prices at `hit_scale == 1.0`
    /// *exactly* (`x / x`).
    pub fn serving_hit_rate(&self, block_len: usize,
                            steps_per_block: usize) -> f64 {
        self.expected_hit_rate(block_len, steps_per_block, REF_N_BLOCKS)
    }
}

/// Canonical block count behind
/// [`CachePolicySpec::serving_hit_rate`]: the serving chat mix's
/// representative generation length (~4 blocks of 64 over the mid
/// seq-len bucket).
pub const REF_N_BLOCKS: usize = 4;

/// Deterministic feature-cache accounting: every consulted step is a
/// lookup, resolved as a hit (features reused) or a miss (features
/// recomputed, `refresh_bytes` restreamed). `hits + misses == lookups`
/// is a structural invariant the property net pins.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub lookups: u64,
    pub hits: u64,
    pub misses: u64,
    /// bytes of refreshed features (logit-buffer traffic) restreamed on
    /// misses
    pub refresh_bytes: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    pub fn merge(&mut self, o: &CacheStats) {
        self.lookups += o.lookups;
        self.hits += o.hits;
        self.misses += o.misses;
        self.refresh_bytes += o.refresh_bytes;
    }
}

/// Stateful per-generation cache driver: the engine asks it for an
/// action at every block-step, feeds committed-token counts back (the
/// adaptive drift proxy), and reports refreshed bytes on misses.
#[derive(Clone, Debug)]
pub struct CachePlanner {
    spec: CachePolicySpec,
    block_len: usize,
    /// steps since response features were last recomputed
    steps_since_refresh: usize,
    /// tokens committed since the last recompute (adaptive drift proxy)
    committed_since_refresh: usize,
    pub stats: CacheStats,
}

impl CachePlanner {
    pub fn new(spec: CachePolicySpec, block_len: usize) -> Self {
        CachePlanner {
            spec,
            block_len: block_len.max(1),
            steps_since_refresh: 0,
            committed_since_refresh: 0,
            stats: CacheStats::default(),
        }
    }

    /// Decide the action for step `t` of block `blk`.
    ///
    /// `baseline_warm` is the pre-cache engine's own warm/refine
    /// decision for this step (warm steps and `CacheMode::None` always
    /// recompute everything); `can_refresh_warm` says whether a
    /// block-start step *could* be served from cached cross-block
    /// features (dual KV cache present, not the first block). `Off`
    /// always returns the baseline action and records nothing.
    pub fn step(&mut self, blk: usize, t: usize, baseline_warm: bool,
                can_refresh_warm: bool) -> CacheAction {
        if self.spec.is_off() {
            return if baseline_warm {
                CacheAction::Full
            } else {
                CacheAction::Refresh
            };
        }
        self.stats.lookups += 1;
        if t == 0 {
            // block start: prompt/prefix features are the cached object
            self.steps_since_refresh = 0;
            self.committed_since_refresh = 0;
            let prompt_stale = match self.spec {
                CachePolicySpec::Interval { prompt_every, .. } =>
                    blk % prompt_every == 0,
                CachePolicySpec::Adaptive { max_interval, .. } =>
                    blk % max_interval == 0,
                CachePolicySpec::Off => unreachable!(),
            };
            if prompt_stale || blk == 0 || !can_refresh_warm {
                self.stats.misses += 1;
                CacheAction::Full
            } else {
                self.stats.hits += 1;
                CacheAction::Refresh
            }
        } else {
            // refine step: the cached block logits are the cached object
            let recompute = match self.spec {
                CachePolicySpec::Interval { response_every, .. } =>
                    self.steps_since_refresh + 1 >= response_every,
                CachePolicySpec::Adaptive { tau, max_interval } =>
                    self.committed_since_refresh as f64
                        / self.block_len as f64 >= tau
                        || self.steps_since_refresh + 1 >= max_interval,
                CachePolicySpec::Off => unreachable!(),
            };
            if recompute {
                self.steps_since_refresh = 0;
                self.committed_since_refresh = 0;
                self.stats.misses += 1;
                if baseline_warm {
                    CacheAction::Full
                } else {
                    CacheAction::Refresh
                }
            } else {
                self.steps_since_refresh += 1;
                self.stats.hits += 1;
                CacheAction::Reuse
            }
        }
    }

    /// Feed the tokens committed this step back into the drift proxy.
    pub fn note_commits(&mut self, n: usize) {
        self.committed_since_refresh += n;
    }

    /// Account refreshed feature bytes (called by the engine on
    /// Full/Refresh steps).
    pub fn note_refresh_bytes(&mut self, bytes: u64) {
        if !self.spec.is_off() {
            self.stats.refresh_bytes += bytes;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_and_defaults() {
        assert_eq!(CachePolicySpec::parse("off"), Some(CachePolicySpec::Off));
        assert_eq!(CachePolicySpec::parse("OFF"), Some(CachePolicySpec::Off));
        assert_eq!(CachePolicySpec::parse("interval"),
                   Some(CachePolicySpec::interval_default()));
        assert_eq!(CachePolicySpec::parse("interval:2:6"),
                   Some(CachePolicySpec::Interval {
                       prompt_every: 2, response_every: 6 }));
        assert_eq!(CachePolicySpec::parse("adaptive"),
                   Some(CachePolicySpec::adaptive_default()));
        assert_eq!(CachePolicySpec::parse("adaptive:0.5:4"),
                   Some(CachePolicySpec::Adaptive {
                       tau: 0.5, max_interval: 4 }));
        assert_eq!(CachePolicySpec::parse("interval:0:4"), None);
        assert_eq!(CachePolicySpec::parse("adaptive:2.0"), None);
        assert_eq!(CachePolicySpec::parse("bogus"), None);
        assert_eq!(CachePolicySpec::default(), CachePolicySpec::Off);
    }

    #[test]
    fn off_matches_baseline_actions_and_records_nothing() {
        let mut p = CachePlanner::new(CachePolicySpec::Off, 8);
        for blk in 0..3 {
            for t in 0..4 {
                let warm = t == 0;
                assert_eq!(p.step(blk, t, warm, blk > 0),
                           if warm { CacheAction::Full }
                           else { CacheAction::Refresh });
            }
        }
        p.note_refresh_bytes(4096);
        assert_eq!(p.stats, CacheStats::default());
    }

    #[test]
    fn degenerate_interval_takes_exactly_the_baseline_actions() {
        // Interval{1,1} refreshes everything at every opportunity: the
        // action stream is identical to Off on every geometry
        for (n_blocks, steps) in [(1usize, 1usize), (3, 4), (4, 16)] {
            let mut cached = CachePlanner::new(
                CachePolicySpec::Interval { prompt_every: 1,
                                            response_every: 1 }, 8);
            let mut off = CachePlanner::new(CachePolicySpec::Off, 8);
            for blk in 0..n_blocks {
                for t in 0..steps {
                    let warm = t == 0;
                    let a = cached.step(blk, t, warm, blk > 0);
                    let b = off.step(blk, t, warm, blk > 0);
                    assert_eq!(a, b, "blk {blk} t {t}");
                    assert_ne!(a, CacheAction::Reuse);
                }
            }
            // degenerate intervals hit nothing — every lookup refreshed
            assert_eq!(cached.stats.hits, 0);
            assert_eq!(cached.stats.misses, cached.stats.lookups);
        }
    }

    #[test]
    fn interval_refresh_cadence() {
        // response_every = 3 on an 8-step block: refreshes at t = 3, 6
        let mut p = CachePlanner::new(
            CachePolicySpec::Interval { prompt_every: 1, response_every: 3 },
            8);
        let mut actions = Vec::new();
        for t in 0..8 {
            actions.push(p.step(0, t, t == 0, false));
        }
        use CacheAction::*;
        assert_eq!(actions, vec![Full, Reuse, Reuse, Refresh, Reuse, Reuse,
                                 Refresh, Reuse]);
        assert_eq!(p.stats.lookups, 8);
        assert_eq!(p.stats.hits, 5);
        assert_eq!(p.stats.misses, 3);
    }

    #[test]
    fn adaptive_drift_forces_refresh() {
        let mut p = CachePlanner::new(
            CachePolicySpec::Adaptive { tau: 0.25, max_interval: 100 }, 8);
        assert_eq!(p.step(0, 0, true, false), CacheAction::Full);
        // below drift threshold: reuse
        p.note_commits(1);
        assert_eq!(p.step(0, 1, false, false), CacheAction::Reuse);
        // 2/8 = 0.25 >= tau: refresh
        p.note_commits(1);
        assert_eq!(p.step(0, 2, false, false), CacheAction::Refresh);
        // drift proxy reset by the refresh
        assert_eq!(p.step(0, 3, false, false), CacheAction::Reuse);
    }

    #[test]
    fn adaptive_max_interval_bounds_staleness() {
        let mut p = CachePlanner::new(
            CachePolicySpec::Adaptive { tau: 1.0, max_interval: 2 }, 64);
        assert_eq!(p.step(0, 0, true, false), CacheAction::Full);
        assert_eq!(p.step(0, 1, false, false), CacheAction::Reuse);
        assert_eq!(p.step(0, 2, false, false), CacheAction::Refresh);
        assert_eq!(p.step(0, 3, false, false), CacheAction::Reuse);
        assert_eq!(p.step(0, 4, false, false), CacheAction::Refresh);
    }

    #[test]
    fn accounting_invariant_holds() {
        crate::stats::prop_check("hits + misses == lookups", 64, |rng| {
            let spec = match rng.next_u64() % 3 {
                0 => CachePolicySpec::interval_default(),
                1 => CachePolicySpec::Interval {
                    prompt_every: 1 + (rng.next_u64() % 6) as usize,
                    response_every: 1 + (rng.next_u64() % 6) as usize,
                },
                _ => CachePolicySpec::Adaptive {
                    tau: 0.1 + 0.8 * rng.next_f64(),
                    max_interval: 1 + (rng.next_u64() % 12) as usize,
                },
            };
            let n_blocks = 1 + (rng.next_u64() % 6) as usize;
            let steps = 1 + (rng.next_u64() % 20) as usize;
            let commits = rng.next_u64();
            (spec, n_blocks, steps, commits)
        }, |&(spec, n_blocks, steps, commits)| {
            let mut p = CachePlanner::new(spec, 16);
            let mut commit_rng = crate::util::SplitMix64::new(commits);
            for blk in 0..n_blocks {
                for t in 0..steps {
                    let a = p.step(blk, t, t == 0, blk > 0);
                    if a != CacheAction::Reuse {
                        p.note_refresh_bytes(1024);
                    }
                    p.note_commits((commit_rng.next_u64() % 4) as usize);
                }
            }
            let s = p.stats;
            if s.hits + s.misses != s.lookups {
                return Err(format!("{} + {} != {}", s.hits, s.misses,
                                   s.lookups));
            }
            if s.lookups != (n_blocks * steps) as u64 {
                return Err(format!("lookups {} != {}", s.lookups,
                                   n_blocks * steps));
            }
            if s.refresh_bytes != s.misses * 1024 {
                return Err("refresh bytes disagree with misses".into());
            }
            Ok(())
        });
    }

    #[test]
    fn hit_rate_monotone_in_refresh_intervals() {
        // driving the planner over a fixed geometry: longer refresh
        // intervals can only raise the hit rate, in both dimensions
        let drive = |p_every: usize, r_every: usize| {
            let mut p = CachePlanner::new(
                CachePolicySpec::Interval { prompt_every: p_every,
                                            response_every: r_every }, 16);
            for blk in 0..8 {
                for t in 0..12 {
                    p.step(blk, t, t == 0, blk > 0);
                }
            }
            p.stats.hit_rate()
        };
        for p_every in 1..6 {
            let mut prev = -1.0;
            for r_every in 1..10 {
                let h = drive(p_every, r_every);
                assert!(h >= prev,
                        "hit rate fell {prev} -> {h} at interval \
                         {p_every}:{r_every}");
                prev = h;
            }
        }
        for r_every in 1..6 {
            let mut prev = -1.0;
            for p_every in 1..10 {
                let h = drive(p_every, r_every);
                assert!(h >= prev, "prompt dimension fell at \
                                    {p_every}:{r_every}");
                prev = h;
            }
        }
    }
}
