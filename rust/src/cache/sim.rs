//! Synthetic feature-drift process (substitution S10): prices a cache
//! policy's *expected* refresh/reuse mix for the analytic serving
//! stack, the way `schedule::sim` (S8) prices expected realized steps.
//!
//! Real dLLM feature-drift traces are not available offline, so the
//! adaptive policy's drift proxy is driven by a seeded synthetic commit
//! process: per refine step, a committed-token count drawn from the
//! same cascade intuition as S8 (commits accelerate as the block
//! denoises). `Interval` and `Off` need no randomness — their plans are
//! exact integer-count ratios, which is what makes
//! `CachePlan::off()` (and `Interval{1,1}`) collapse to exactly
//! `{1.0, 1.0}` and keep [`crate::sim::analytical::AnalyticalSim::run_cached`]
//! bit-identical to `run_scheduled` when the cache is off.

use crate::util::SplitMix64;

use super::policy::{CacheAction, CachePlanner, CachePolicySpec};

/// Fixed seed set for expectation estimates: means over these seeds are
/// deterministic across runs and platforms (disjoint from
/// `schedule::sim::EXPECTATION_SEEDS` so the two synthetic processes
/// never share draws).
pub const EXPECTATION_SEEDS: [u64; 4] = [13, 31, 59, 83];

/// Realized cache behaviour of one simulated block.
#[derive(Clone, Copy, Debug)]
pub struct CacheBlockTrace {
    /// did the block-start step run the full (prompt-refreshing) pass?
    pub warm_full: bool,
    /// refine steps that recomputed response features
    pub refreshes: usize,
    /// refine steps served from the cache
    pub reuses: usize,
}

/// Expected refresh mix of a policy at a block geometry: the two
/// fractions every analytic pricing layer bills from. Both are exact
/// integer-count ratios, so `off()` — and any policy whose counts are
/// total — reproduces `{1.0, 1.0}` bit-exactly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CachePlan {
    /// fraction of block-start steps run as full warm forwards
    pub warm_full_frac: f64,
    /// fraction of refine steps that recompute response features
    pub refresh_frac: f64,
}

impl CachePlan {
    /// The cache-off plan: everything recomputed, bit-exact baseline.
    pub fn off() -> Self {
        CachePlan { warm_full_frac: 1.0, refresh_frac: 1.0 }
    }

    /// Expected cache hit rate over one block's `steps_per_block`
    /// feature lookups (one warm + `steps_per_block − 1` refines).
    pub fn hit_rate(&self, steps_per_block: f64) -> f64 {
        if steps_per_block < 1.0 {
            return 0.0;
        }
        ((1.0 - self.warm_full_frac)
         + (1.0 - self.refresh_frac) * (steps_per_block - 1.0))
            / steps_per_block
    }
}

/// Drive one block of `steps` denoising steps through the planner under
/// the synthetic commit process. `blk` / `can_refresh_warm` position
/// the block in its generation (block 0 always runs the full warm
/// pass). Deterministic in `seed`.
pub fn simulate_cache_block(planner: &mut CachePlanner, block_len: usize,
                            steps: usize, blk: usize,
                            can_refresh_warm: bool, seed: u64)
                            -> CacheBlockTrace {
    let mut rng = SplitMix64::new(seed ^ 0xFEA7_CACE ^ (blk as u64) << 8);
    let mut trace = CacheBlockTrace {
        warm_full: false,
        refreshes: 0,
        reuses: 0,
    };
    let mut remaining = block_len;
    for t in 0..steps.max(1) {
        let action = planner.step(blk, t, t == 0, can_refresh_warm);
        match action {
            CacheAction::Full => {
                if t == 0 {
                    trace.warm_full = true;
                } else {
                    trace.refreshes += 1;
                }
            }
            CacheAction::Refresh => {
                if t > 0 {
                    trace.refreshes += 1;
                }
            }
            CacheAction::Reuse => trace.reuses += 1,
        }
        // synthetic commit cascade: early steps commit little, late
        // steps sweep the remainder — the S8 intuition, feeding the
        // adaptive policy's drift proxy
        let steps_left = (steps - t).max(1);
        let base = remaining as f64 / steps_left as f64;
        let k = ((base * (0.5 + rng.next_f64())).round() as usize)
            .clamp(if remaining > 0 { 1 } else { 0 }, remaining);
        remaining -= k;
        planner.note_commits(k);
    }
    trace
}

/// Expected refresh mix of `spec` at a block geometry, mean over the
/// fixed seed set for the adaptive (stochastic-drift) policy and exact
/// for `Off`/`Interval`.
pub fn expected_plan(spec: &CachePolicySpec, block_len: usize,
                     steps_per_block: usize, n_blocks: usize) -> CachePlan {
    let steps = steps_per_block.max(1);
    let blocks = n_blocks.max(1);
    match *spec {
        CachePolicySpec::Off => CachePlan::off(),
        CachePolicySpec::Interval { prompt_every, response_every } => {
            // full warm passes: blocks 0, p, 2p, …
            let fulls = (0..blocks).filter(|b| b % prompt_every == 0)
                .count();
            // refreshes on refine steps: cadence r over steps 1..S
            let refines = steps - 1;
            let refreshes = refines / response_every;
            CachePlan {
                warm_full_frac: fulls as f64 / blocks as f64,
                refresh_frac: if refines == 0 {
                    1.0
                } else {
                    refreshes as f64 / refines as f64
                },
            }
        }
        CachePolicySpec::Adaptive { .. } => {
            let mut fulls = 0usize;
            let mut refreshes = 0usize;
            let mut refines = 0usize;
            for &seed in &EXPECTATION_SEEDS {
                let mut planner = spec.build(block_len);
                for blk in 0..blocks {
                    let t = simulate_cache_block(
                        &mut planner, block_len, steps, blk, blk > 0,
                        seed);
                    if t.warm_full {
                        fulls += 1;
                    }
                    refreshes += t.refreshes;
                    refines += t.refreshes + t.reuses;
                }
            }
            CachePlan {
                warm_full_frac: fulls as f64
                    / (blocks * EXPECTATION_SEEDS.len()) as f64,
                refresh_frac: if refines == 0 {
                    1.0
                } else {
                    refreshes as f64 / refines as f64
                },
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_plan_is_exactly_one_one() {
        let p = expected_plan(&CachePolicySpec::Off, 64, 16, 4);
        assert_eq!(p.warm_full_frac.to_bits(), 1.0f64.to_bits());
        assert_eq!(p.refresh_frac.to_bits(), 1.0f64.to_bits());
        assert_eq!(p.hit_rate(16.0).to_bits(), 0.0f64.to_bits());
    }

    #[test]
    fn degenerate_interval_plan_matches_off_bit_exactly() {
        let p = expected_plan(
            &CachePolicySpec::Interval { prompt_every: 1,
                                         response_every: 1 }, 64, 16, 4);
        assert_eq!(p.warm_full_frac.to_bits(), 1.0f64.to_bits());
        assert_eq!(p.refresh_frac.to_bits(), 1.0f64.to_bits());
        assert_eq!(p, CachePlan::off());
    }

    #[test]
    fn interval_plan_counts_exactly() {
        // 4 blocks, prompt_every 2 -> fulls at blocks 0, 2; 16 steps,
        // response_every 4 -> refreshes at t = 4, 8, 12 of 15 refines
        let p = expected_plan(
            &CachePolicySpec::Interval { prompt_every: 2,
                                         response_every: 4 }, 64, 16, 4);
        assert_eq!(p.warm_full_frac, 2.0 / 4.0);
        assert_eq!(p.refresh_frac, 3.0 / 15.0);
        let h = p.hit_rate(16.0);
        assert!(h > 0.0 && h < 1.0, "hit rate {h}");
    }

    #[test]
    fn adaptive_plan_is_deterministic_and_nontrivial() {
        let spec = CachePolicySpec::adaptive_default();
        let a = expected_plan(&spec, 64, 16, 4);
        let b = expected_plan(&spec, 64, 16, 4);
        assert_eq!(a.warm_full_frac.to_bits(), b.warm_full_frac.to_bits());
        assert_eq!(a.refresh_frac.to_bits(), b.refresh_frac.to_bits());
        // the adaptive policy must actually reuse something, but never
        // everything (it refreshes on drift)
        assert!(a.refresh_frac > 0.0 && a.refresh_frac < 1.0,
                "refresh frac {}", a.refresh_frac);
        let h = a.hit_rate(16.0);
        assert!(h > 0.0 && h < 1.0, "hit rate {h}");
    }

    #[test]
    fn tighter_tau_refreshes_more() {
        let plan = |tau| expected_plan(
            &CachePolicySpec::Adaptive { tau, max_interval: 16 },
            64, 16, 4);
        assert!(plan(0.05).refresh_frac >= plan(0.5).refresh_frac,
                "tighter drift threshold must refresh at least as often");
    }

    #[test]
    fn simulated_block_accounts_every_step() {
        for &seed in &EXPECTATION_SEEDS {
            let mut planner =
                CachePolicySpec::adaptive_default().build(32);
            let t = simulate_cache_block(&mut planner, 32, 12, 0, false,
                                         seed);
            assert!(t.warm_full, "block 0 must run the full warm pass");
            assert_eq!(t.refreshes + t.reuses, 11,
                       "11 refine steps must all be accounted");
            let s = planner.stats;
            assert_eq!(s.hits + s.misses, s.lookups);
            assert_eq!(s.lookups, 12);
        }
    }
}
