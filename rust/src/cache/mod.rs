//! Cross-step feature caching: a serving dimension for the redundancy
//! between adjacent denoising steps.
//!
//! dLLM-Cache (and DPad after it) observe that a diffusion LLM's
//! features barely change between adjacent denoising steps — prompt
//! features are near-static across a generation, response features
//! drift slowly between refreshes — and turn that redundancy into
//! multi-fold speedups by refreshing features at intervals instead of
//! every step. This subsystem models that as a first-class serving
//! dimension:
//!
//! * [`policy`] — [`CachePolicySpec`] (`Off` bit-exact with the
//!   pre-cache engine, `Interval` with fixed prompt/response refresh
//!   cadences, `Adaptive` driven by a committed-token drift proxy), the
//!   stateful [`CachePlanner`] the generation engine steps through, and
//!   the deterministic [`CacheStats`] accounting
//!   (hits + misses == lookups, property-gated).
//! * [`sim`] — the seeded synthetic feature-drift process (substitution
//!   S10, the cache analogue of `schedule::sim`'s S8) that prices a
//!   policy's *expected* refresh/reuse mix ([`CachePlan`]) for every
//!   analytic cost model:
//!   [`crate::sim::analytical::AnalyticalSim::run_cached`] bills only
//!   refreshed-feature FLOPs/bytes, calibration records the expected
//!   hit rate on every [`crate::calib::LatencyCurve`] (text format v3),
//!   and the cluster scheduler's admission prices warm steady-state
//!   serving against cold first blocks from it.
//!
//! The policy decides *when* features are recomputed; *what* a step
//! computes is unchanged — so `Off` (the default) and the degenerate
//! `Interval { 1, 1 }` reproduce the pre-cache engine bit-exactly
//! (`rust/tests/cache_equivalence.rs` is the differential gate, bench
//! `cache_sweep` proves the cached arms are distinguishable).

pub mod policy;
pub mod sim;

pub use policy::{CacheAction, CachePlanner, CachePolicySpec, CacheStats,
                 REF_N_BLOCKS};
pub use sim::{expected_plan, simulate_cache_block, CacheBlockTrace,
              CachePlan, EXPECTATION_SEEDS};
