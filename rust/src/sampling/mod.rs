//! The diffusion sampling engine — golden functional model (paper §3.2).
//!
//! This is the Rust twin of the L1 Pallas sampling kernels and the
//! *actual production sampler* on the serving path: the coordinator
//! feeds PJRT-produced logits through [`sample_block`] to commit tokens.
//! Semantics are locked to `python/compile/kernels/ref.py` via the
//! manifest goldens (integration tests).
//!
//! The four phases of Alg. 2:
//!   1. Stable-Max + fused max-with-index over streamed V_chunks
//!      ([`stable_max_confidence`]);
//!   2. scalar write-back (confidence → FP domain, argmax → Int domain);
//!   3. streaming insertion top-k ([`topk_mask`], O(k) comparator chain);
//!   4. masked integer update ([`masked_select`]).

/// Sampling-stage arithmetic precision (paper §6.1: FP64 reference
/// software config vs BF16 vs MXFP8 on-chip).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplePrecision {
    Fp64,
    Fp32,
    Bf16,
    MxFp8,
}

impl SamplePrecision {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fp64" => Some(Self::Fp64),
            "fp32" => Some(Self::Fp32),
            "bf16" => Some(Self::Bf16),
            "mxfp8" => Some(Self::MxFp8),
            _ => None,
        }
    }

    fn prep(&self, z: &[f32]) -> Vec<f32> {
        match self {
            Self::Fp64 | Self::Fp32 => z.to_vec(),
            Self::Bf16 => z.iter().map(|&v| crate::quant::bf16_roundtrip(v)).collect(),
            Self::MxFp8 => {
                if z.len() % crate::quant::MX_BLOCK == 0 {
                    crate::quant::fake_quant(z, crate::quant::MxFormat::MxFp8)
                } else {
                    z.to_vec()
                }
            }
        }
    }
}

/// Phase 1: Stable-Max confidence + argmax over one V-long logit row,
/// streamed in `v_chunk` tiles (Eq. 3: conf = 1/Σ exp(z_j − m)).
///
/// Chunked exactly like the hardware: pass 1 folds per-chunk
/// (max, argmax) into a scalar carry (V_RED_MAX_IDX), pass 2 accumulates
/// Σ exp(z − m) (V_EXP_V in place + V_RED_SUM), then S_RECIP.
/// Strict `>` keeps the earliest index on ties.
pub fn stable_max_confidence(z: &[f32], v_chunk: usize) -> (f32, u32) {
    debug_assert!(!z.is_empty());
    let v_chunk = v_chunk.max(1).min(z.len());
    // pass 1: fused max-with-index. The value reduction is a branchless
    // fold (auto-vectorizes); the index scan runs only when a chunk
    // improves the global max — rare after the first chunks
    // (§Perf iteration 3: ~1.7x on the scan).
    let mut m = f32::NEG_INFINITY;
    let mut mi = 0u32;
    for (c, chunk) in z.chunks(v_chunk).enumerate() {
        let cm = chunk.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        if cm > m {
            m = cm;
            // first occurrence of cm — ties keep the earliest index
            let ci = chunk.iter().position(|&v| v == cm).unwrap();
            mi = (c * v_chunk + ci) as u32;
        }
    }
    // pass 2: denominator accumulation. f32 exp (the hardware's V_EXP_V
    // and the jnp oracle both evaluate exp in f32) with f64 chunk
    // accumulation — ~2.5x faster than f64 exp with identical oracle
    // agreement (§Perf iteration 1).
    let mut denom = 0f64;
    for chunk in z.chunks(v_chunk) {
        let mut acc = 0f32;
        for &val in chunk {
            acc += (val - m).exp();
        }
        denom += acc as f64;
    }
    ((1.0 / denom) as f32, mi)
}

/// Phase 1 over a [N, V] logit matrix with precision modeling.
pub fn confidence_argmax(z: &[f32], n: usize, v: usize, v_chunk: usize,
                         prec: SamplePrecision) -> (Vec<f32>, Vec<u32>) {
    assert_eq!(z.len(), n * v);
    let mut conf = Vec::with_capacity(n);
    let mut idx = Vec::with_capacity(n);
    for row in 0..n {
        let zr = prec.prep(&z[row * v..(row + 1) * v]);
        let (c, i) = stable_max_confidence(&zr, v_chunk);
        conf.push(c);
        idx.push(i);
    }
    (conf, idx)
}

/// Phase 3: V_TOPK_MASK — streaming insertion top-k with an O(k)-area
/// comparator chain. `mask[i] != 0` marks eligible (still-masked)
/// positions; returns a boolean transfer mask with exactly
/// `min(k, #eligible)` bits set. Strict `>` insertion ⇒ ties resolve to
/// the earliest index (matches ref.topk_mask_ref and the Pallas kernel).
pub fn topk_mask(conf: &[f32], mask: &[i32], k: usize) -> Vec<bool> {
    let l = conf.len();
    assert_eq!(mask.len(), l);
    let k = k.min(l);
    let mut out = vec![false; l];
    if k == 0 {
        return out;
    }
    // comparator chain registers: (value, index), sorted descending
    let mut vals = vec![f32::NEG_INFINITY; k];
    let mut idxs = vec![usize::MAX; k];
    for i in 0..l {
        if mask[i] == 0 {
            continue;
        }
        let mut cur_v = conf[i];
        let mut cur_i = i;
        for j in 0..k {
            if cur_v > vals[j] {
                std::mem::swap(&mut cur_v, &mut vals[j]);
                std::mem::swap(&mut cur_i, &mut idxs[j]);
            }
        }
    }
    for j in 0..k {
        if idxs[j] != usize::MAX {
            out[idxs[j]] = true;
        }
    }
    out
}

/// Phase 4: V_SELECT_INT — out[i] = mask[i] ? a[i] : b[i].
pub fn masked_select(mask: &[bool], a: &[i32], b: &[i32]) -> Vec<i32> {
    mask.iter()
        .zip(a.iter().zip(b))
        .map(|(&m, (&x, &y))| if m { x } else { y })
        .collect()
}

/// Result of one intra-block sampling step.
#[derive(Clone, Debug)]
pub struct SampleResult {
    pub x_new: Vec<i32>,
    pub conf: Vec<f32>,
    pub argmax: Vec<i32>,
    pub transfer: Vec<bool>,
}

/// Phases 3–4 of Alg. 2 over precomputed phase-1 outputs: top-k
/// commitment and masked update for a [B, L] grid, given the per-position
/// confidences and argmaxes that [`confidence_argmax`] produced.
///
/// Split out of [`sample_block`] so a schedule policy
/// ([`crate::schedule::SchedulePolicy`]) can observe the live confidence
/// vector *before* choosing how many tokens each row commits this step
/// — the commit path itself is byte-for-byte the one `sample_block`
/// always ran.
pub fn commit_block(conf: &[f32], idx: &[u32], x: &[i32], b: usize,
                    l: usize, k: &[usize], mask_id: i32) -> SampleResult {
    assert_eq!(conf.len(), b * l);
    assert_eq!(idx.len(), b * l);
    assert_eq!(x.len(), b * l);
    assert_eq!(k.len(), b);
    let argmax: Vec<i32> = idx.iter().map(|&i| i as i32).collect();
    let mut x_new = Vec::with_capacity(b * l);
    let mut transfer_all = Vec::with_capacity(b * l);
    for bi in 0..b {
        let row = bi * l..(bi + 1) * l;
        let m_idx: Vec<i32> = x[row.clone()].iter()
            .map(|&t| (t == mask_id) as i32).collect();
        let transfer = topk_mask(&conf[row.clone()], &m_idx, k[bi]);
        // x0 = where(masked, argmax, x); x_new = where(transfer, x0, x)
        let masked: Vec<bool> = m_idx.iter().map(|&m| m != 0).collect();
        let x0 = masked_select(&masked, &argmax[row.clone()], &x[row.clone()]);
        let xn = masked_select(&transfer, &x0, &x[row.clone()]);
        x_new.extend_from_slice(&xn);
        transfer_all.extend_from_slice(&transfer);
    }
    SampleResult { x_new, conf: conf.to_vec(), argmax,
                   transfer: transfer_all }
}

/// Full Alg. 2 intra-block step over a [B, L, V] logit tensor.
///
/// `x` is the current [B, L] token grid; `k[b]` tokens are committed per
/// row. Returns the updated grid plus the intermediate tensors (the
/// cycle simulator cross-checks against these).
pub fn sample_block(z: &[f32], x: &[i32], b: usize, l: usize, v: usize,
                    k: &[usize], mask_id: i32, v_chunk: usize,
                    prec: SamplePrecision) -> SampleResult {
    assert_eq!(z.len(), b * l * v);
    let (conf, idx) = confidence_argmax(z, b * l, v, v_chunk, prec);
    commit_block(&conf, &idx, x, b, l, k, mask_id)
}

/// An invalid fixed transfer schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleError {
    /// `steps == 0` — the per-step division is undefined.
    ZeroSteps,
    /// `steps > block_len` — the tail steps would commit zero tokens
    /// (each a full model forward that changes nothing).
    StepsExceedBlock { block_len: usize, steps: usize },
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::ZeroSteps =>
                write!(f, "transfer schedule needs at least one step"),
            ScheduleError::StepsExceedBlock { block_len, steps } =>
                write!(f, "{steps} steps over a {block_len}-token block \
                           would run zero-token steps"),
        }
    }
}

impl std::error::Error for ScheduleError {}

/// The LLaDA transfer schedule: tokens committed at each of `steps`
/// denoising steps for a block of `block_len` (remainder to early
/// steps). Validated: `steps == 0` (division by zero) and
/// `steps > block_len` (zero-token steps) are errors, so every returned
/// schedule sums to `block_len` with every entry positive.
pub fn num_transfer_tokens(block_len: usize, steps: usize)
                           -> Result<Vec<usize>, ScheduleError> {
    if steps == 0 {
        return Err(ScheduleError::ZeroSteps);
    }
    if steps > block_len {
        return Err(ScheduleError::StepsExceedBlock { block_len, steps });
    }
    let base = block_len / steps;
    let rem = block_len % steps;
    Ok((0..steps).map(|t| base + usize::from(t < rem)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    fn softmax_max(z: &[f32]) -> (f32, usize) {
        let m = z.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let denom: f64 = z.iter().map(|&v| ((v - m) as f64).exp()).sum();
        let idx = z.iter().position(|&v| v == m).unwrap();
        ((1.0 / denom) as f32, idx)
    }

    #[test]
    fn stable_max_matches_softmax() {
        let mut rng = SplitMix64::new(0);
        let z = rng.normal_vec(256, 4.0);
        let (c, i) = stable_max_confidence(&z, 64);
        let (cr, ir) = softmax_max(&z);
        assert!((c - cr).abs() < 1e-6);
        assert_eq!(i as usize, ir);
    }

    #[test]
    fn chunk_invariance() {
        let mut rng = SplitMix64::new(1);
        let z = rng.normal_vec(512, 3.0);
        let base = stable_max_confidence(&z, 512);
        for chunk in [1, 7, 64, 128, 511] {
            let got = stable_max_confidence(&z, chunk);
            assert_eq!(got.1, base.1, "chunk {chunk}");
            assert!((got.0 - base.0).abs() < 1e-6);
        }
    }

    #[test]
    fn large_logits_no_overflow() {
        let mut z = vec![300.0f32; 128];
        z[17] = 400.0;
        let (c, i) = stable_max_confidence(&z, 32);
        assert!(c.is_finite() && c > 0.0);
        assert_eq!(i, 17);
    }

    #[test]
    fn tie_takes_earliest() {
        let mut z = vec![0f32; 64];
        z[10] = 2.0;
        z[40] = 2.0;
        assert_eq!(stable_max_confidence(&z, 16).1, 10);
    }

    #[test]
    fn topk_basic() {
        let conf = [0.1, 0.9, 0.3, 0.8, 0.2, 0.7, 0.0, 0.5];
        let mask = [1i32; 8];
        let got = topk_mask(&conf, &mask, 3);
        assert_eq!(got, [false, true, false, true, false, true, false, false]);
    }

    #[test]
    fn topk_respects_mask_and_k() {
        let conf = [0.9, 0.8, 0.7, 0.6];
        let mask = [0, 1, 0, 1];
        assert_eq!(topk_mask(&conf, &mask, 2), [false, true, false, true]);
        assert_eq!(topk_mask(&conf, &[1; 4], 0), [false; 4]);
    }

    #[test]
    fn topk_property_counts() {
        crate::stats::prop_check("topk count == min(k, eligible)", 64, |rng| {
            let l = 4 + (rng.next_u64() % 60) as usize;
            let conf: Vec<f32> = (0..l).map(|_| rng.next_f32()).collect();
            let mask: Vec<i32> = (0..l).map(|_| (rng.next_u64() % 2) as i32).collect();
            let k = (rng.next_u64() % (l as u64 + 4)) as usize;
            (conf, mask, k)
        }, |(conf, mask, k)| {
            let got = topk_mask(conf, mask, *k);
            let eligible = mask.iter().filter(|&&m| m != 0).count();
            let set = got.iter().filter(|&&b| b).count();
            if set != (*k).min(eligible).min(conf.len()) {
                return Err(format!("set {set}, k {k}, eligible {eligible}"));
            }
            // selected ⊆ eligible, and selected conf >= any unselected eligible conf
            let min_sel = got.iter().zip(conf).filter(|(&g, _)| g)
                .map(|(_, &c)| c).fold(f32::INFINITY, f32::min);
            for i in 0..conf.len() {
                if got[i] && mask[i] == 0 {
                    return Err("selected ineligible".into());
                }
                if !got[i] && mask[i] != 0 && set < conf.len() && conf[i] > min_sel {
                    return Err(format!("unselected {} > min selected {}",
                                       conf[i], min_sel));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sample_block_commits_k_per_row() {
        let mut rng = SplitMix64::new(2);
        let (b, l, v) = (2usize, 16usize, 64usize);
        let z = rng.normal_vec(b * l * v, 3.0);
        let mut x = vec![0i32; b * l]; // all masked
        for i in 0..4 {
            x[i] = 7; // some already decoded
        }
        let r = sample_block(&z, &x, b, l, v, &[3, 5], 0, 16,
                             SamplePrecision::Fp32);
        for bi in 0..b {
            // transfer count is the commitment signal (an argmax of 0 ==
            // mask_id would be committed yet still *look* masked)
            let committed = (0..l).filter(|&i| r.transfer[bi * l + i]).count();
            assert_eq!(committed, [3, 5][bi]);
            // transfers only land on masked positions
            for i in 0..l {
                if r.transfer[bi * l + i] {
                    assert_eq!(x[bi * l + i], 0);
                    assert_eq!(r.x_new[bi * l + i], r.argmax[bi * l + i]);
                }
            }
        }
        // unmasked positions unchanged
        for i in 0..4 {
            assert_eq!(r.x_new[i], 7);
        }
    }

    #[test]
    fn precision_modes_mostly_agree() {
        let mut rng = SplitMix64::new(3);
        let (n, v) = (64usize, 128usize);
        let z = rng.normal_vec(n * v, 4.0);
        let (_, base) = confidence_argmax(&z, n, v, 64, SamplePrecision::Fp32);
        for (prec, thresh) in [(SamplePrecision::Bf16, 9), (SamplePrecision::MxFp8, 8)] {
            let (_, got) = confidence_argmax(&z, n, v, 64, prec);
            let agree = base.iter().zip(&got).filter(|(a, b)| a == b).count();
            assert!(agree * 10 >= n * thresh, "{prec:?} agree {agree}/{n}");
        }
    }

    #[test]
    fn transfer_schedule() {
        assert_eq!(num_transfer_tokens(16, 8).unwrap(), vec![2; 8]);
        assert_eq!(num_transfer_tokens(7, 3).unwrap(), vec![3, 2, 2]);
        assert_eq!(num_transfer_tokens(16, 5).unwrap()
                       .iter().sum::<usize>(), 16);
    }

    #[test]
    fn transfer_schedule_rejects_degenerate_steps() {
        // steps == 0 used to divide by zero; steps > block_len used to
        // emit zero-token steps (wasted full model forwards)
        assert_eq!(num_transfer_tokens(16, 0), Err(ScheduleError::ZeroSteps));
        assert_eq!(num_transfer_tokens(4, 9),
                   Err(ScheduleError::StepsExceedBlock {
                       block_len: 4, steps: 9 }));
        // the boundary is valid: one token per step
        assert_eq!(num_transfer_tokens(4, 4).unwrap(), vec![1; 4]);
        assert_eq!(num_transfer_tokens(1, 1).unwrap(), vec![1]);
        // errors render for CLI surfaces
        assert!(ScheduleError::ZeroSteps.to_string().contains("step"));
        assert!(num_transfer_tokens(4, 9).unwrap_err().to_string()
                    .contains("zero-token"));
    }

    #[test]
    fn transfer_schedule_entries_all_positive_and_sum_to_block() {
        crate::stats::prop_check("validated schedule shape", 64, |rng| {
            let block = 1 + (rng.next_u64() % 96) as usize;
            let steps = 1 + (rng.next_u64() % block as u64) as usize;
            (block, steps)
        }, |&(block, steps)| {
            let ks = num_transfer_tokens(block, steps)
                .map_err(|e| e.to_string())?;
            if ks.len() != steps {
                return Err(format!("{} entries for {steps} steps", ks.len()));
            }
            if ks.iter().sum::<usize>() != block {
                return Err(format!("sum {} != {block}",
                                   ks.iter().sum::<usize>()));
            }
            if ks.iter().any(|&k| k == 0) {
                return Err("zero-token step in validated schedule".into());
            }
            Ok(())
        });
    }

    #[test]
    fn commit_block_matches_sample_block_exactly() {
        // the split phase-1 / phase-3–4 path must be bit-identical to
        // the fused sample_block (the schedule layer relies on this)
        let mut rng = SplitMix64::new(5);
        let (b, l, v) = (3usize, 12usize, 96usize);
        let z = rng.normal_vec(b * l * v, 3.0);
        let mut x = vec![0i32; b * l];
        x[2] = 9;
        x[15] = 11;
        let k = [2usize, 4, 6];
        let fused = sample_block(&z, &x, b, l, v, &k, 0, 32,
                                 SamplePrecision::Fp32);
        let (conf, idx) = confidence_argmax(&z, b * l, v, 32,
                                            SamplePrecision::Fp32);
        let split = commit_block(&conf, &idx, &x, b, l, &k, 0);
        assert_eq!(split.x_new, fused.x_new);
        assert_eq!(split.transfer, fused.transfer);
        assert_eq!(split.argmax, fused.argmax);
        for (a, bb) in split.conf.iter().zip(&fused.conf) {
            assert_eq!(a.to_bits(), bb.to_bits());
        }
    }
}
