//! The calibration profiler: drive every compiled batch variant of a
//! device through the tri-path simulator and distill the measurements
//! into a [`LatencyCurve`].
//!
//! The fast path is the analytical simulator ([`AnalyticalSim`]): each
//! (variant × seq-len-bucket) cell is profiled over several *jittered*
//! workloads drawn inside the bucket (deterministic [`SplitMix64`]
//! seed), so the recorded p50/p95 spread reflects the real in-bucket
//! shape variation the scheduler will face — not a synthetic error bar.
//!
//! [`spot_check_sampling`] closes the loop against ground truth: the
//! compiled Algorithm 2 program is executed on the cycle-accurate
//! simulator at a matched shape and compared with the analytical
//! sampling-step latency (the Table 4 cross-validation, in-process).

use crate::cache::{expected_plan, CachePolicySpec, REF_N_BLOCKS};
use crate::compiler::{sampling_program, SamplingLayout};
use crate::config::{CacheMode, HwConfig, ModelArch, Workload};
use crate::sampling::SamplePrecision;
use crate::schedule::ScheduleSpec;
use crate::sim::analytical::{AnalyticalSim, PrecisionConfig};
use crate::sim::cycle::CycleSim;
use crate::stats::quantile;
use crate::util::SplitMix64;
use crate::window::WindowPolicySpec;

use super::curve::{CurvePoint, LatencyCurve};

/// What to profile: the variant set, the total-sequence-length buckets,
/// and how many jittered workloads to draw per cell.
#[derive(Clone, Debug)]
pub struct CalibConfig {
    /// compiled batch variants, ascending
    pub variants: Vec<usize>,
    /// `[lo, hi)` total-sequence-length (prompt + gen) buckets
    pub buckets: Vec<(u64, u64)>,
    /// jittered workload draws per (variant, bucket) cell
    pub samples_per_cell: usize,
    pub block_len: u64,
    pub steps_per_block: u64,
    /// denoising-schedule policy the profile bills: cells are priced at
    /// the policy's *expected realized* steps per block, and the curve
    /// records that expectation ([`LatencyCurve::expected_steps`])
    pub schedule: ScheduleSpec,
    /// feature-cache policy the profile bills: cells are priced at the
    /// policy's expected refresh/reuse mix
    /// ([`crate::cache::CachePlan`]) and the curve records the hit-rate
    /// expectation ([`LatencyCurve::cache_hit_rate`])
    pub feature_cache: CachePolicySpec,
    /// suffix-window policy the profile bills: cells are priced at the
    /// policy's per-block active-suffix fractions and the curve records
    /// the serving expectation ([`LatencyCurve::window_frac`])
    pub window: WindowPolicySpec,
    pub seed: u64,
}

impl CalibConfig {
    /// The serving-stack default: the chat mix's length range in four
    /// power-of-two buckets over the paper's §6.2 block geometry.
    pub fn serving_default(variants: &[usize]) -> Self {
        let mut variants = variants.to_vec();
        variants.sort_unstable();
        variants.dedup();
        if variants.is_empty() {
            variants.push(1);
        }
        CalibConfig {
            variants,
            buckets: vec![(96, 256), (256, 512), (512, 1024), (1024, 2048)],
            samples_per_cell: 5,
            block_len: 64,
            steps_per_block: 16,
            schedule: ScheduleSpec::Fixed,
            feature_cache: CachePolicySpec::Off,
            window: WindowPolicySpec::Full,
            seed: 0xCA11B,
        }
    }
}

/// Profiles one hardware point into a [`LatencyCurve`].
pub struct Calibrator {
    sim: AnalyticalSim,
    model: ModelArch,
    cache: CacheMode,
    pub cfg: CalibConfig,
}

impl Calibrator {
    pub fn new(hw: HwConfig, model: ModelArch, cache: CacheMode,
               cfg: CalibConfig) -> Self {
        let sim = AnalyticalSim::new(hw, PrecisionConfig::dart_full_quant());
        Calibrator { sim, model, cache, cfg }
    }

    /// Draw one jittered workload inside a bucket: total length uniform
    /// in `[lo, hi)`, generation taking ~2/3 of it rounded to whole
    /// blocks (the blocked-diffusion commit granularity).
    fn draw_workload(&self, rng: &mut SplitMix64, variant: usize,
                     lo: u64, hi: u64) -> Workload {
        let block = self.cfg.block_len.max(1);
        let total = rng.range(lo, hi.max(lo + 1));
        let mut gen = (2 * total / 3 / block).max(1) * block;
        if gen + 8 > total {
            gen = block;
        }
        let prompt = total.saturating_sub(gen).max(8);
        Workload {
            model: self.model.clone(),
            batch: variant as u64,
            prompt_len: prompt,
            gen_len: gen,
            block_len: block,
            steps_per_block: self.cfg.steps_per_block,
            cache: self.cache,
        }
    }

    /// Profile every (variant, bucket) cell into a curve for `device`.
    /// Cells are billed at the configured schedule's expected realized
    /// steps per block (identical to the legacy fixed-cap pricing when
    /// the schedule is [`ScheduleSpec::Fixed`]).
    pub fn profile(&self, device: &str) -> LatencyCurve {
        let expected_steps = self.cfg.schedule.expected_steps(
            self.cfg.block_len as usize, self.cfg.steps_per_block as usize);
        // one expected refresh/reuse mix at the canonical serving
        // geometry prices every cell (the expected-steps treatment,
        // mirrored); Off is exactly {1.0, 1.0} so cache-off profiles
        // stay bit-identical to the pre-cache profiler
        let plan = expected_plan(&self.cfg.feature_cache,
                                 self.cfg.block_len as usize,
                                 self.cfg.steps_per_block as usize,
                                 REF_N_BLOCKS);
        let hit_rate = self.cfg.feature_cache.serving_hit_rate(
            self.cfg.block_len as usize, self.cfg.steps_per_block as usize);
        // one serving active-suffix expectation tags the curve; Full is
        // exactly 1.0 and run_windowed is bit-identical to run_cached
        // there, so full-suffix profiles stay bit-identical to the
        // pre-window profiler
        let window_frac =
            self.cfg.window.serving_active_frac(self.cfg.block_len as usize);
        let mut points = Vec::new();
        for &variant in &self.cfg.variants {
            for &(lo, hi) in &self.cfg.buckets {
                // seeded per *bucket* (not per variant): every variant
                // profiles the identical jittered workload draws, so
                // cross-variant cost comparisons (the batcher's
                // exact-fill-vs-pad-up split) are apples-to-apples
                let mut rng = SplitMix64::new(self.cfg.seed ^ lo);
                let n = self.cfg.samples_per_cell.max(1);
                let mut totals = Vec::with_capacity(n);
                let mut firsts = Vec::with_capacity(n);
                let mut gen_sum = 0u64;
                for _ in 0..n {
                    let w = self.draw_workload(&mut rng, variant, lo, hi);
                    let total =
                        self.sim.run_windowed(&w, expected_steps, &plan,
                                              &self.cfg.window)
                            .total_s;
                    totals.push(total);
                    firsts.push(total / w.n_blocks().max(1) as f64);
                    gen_sum += w.gen_len;
                }
                points.push(CurvePoint {
                    variant,
                    bucket_lo: lo,
                    bucket_hi: hi,
                    gen_tokens: gen_sum / n as u64,
                    p50_total_s: quantile(&totals, 0.50),
                    p95_total_s: quantile(&totals, 0.95),
                    p50_first_s: quantile(&firsts, 0.50),
                    p95_first_s: quantile(&firsts, 0.95),
                    samples: n as u32,
                });
            }
        }
        LatencyCurve::new(device, points)
            .with_schedule(self.cfg.steps_per_block, expected_steps)
            .with_cache(hit_rate)
            .with_window(window_frac)
    }
}

/// Result of one analytical-vs-cycle spot check on a sampling step.
#[derive(Clone, Copy, Debug)]
pub struct SpotCheck {
    pub analytical_s: f64,
    pub cycle_s: f64,
    pub cycles: u64,
}

impl SpotCheck {
    /// |analytical − cycle| / cycle.
    pub fn rel_err(&self) -> f64 {
        crate::util::rel_err(self.analytical_s, self.cycle_s)
    }
}

/// Execute the compiled Algorithm 2 program on the cycle-accurate
/// simulator at `(b, l, v, v_chunk)` and compare against the analytical
/// sampling-step latency — the Table 4 cross-validation as a callable.
/// SRAM domains are sized exactly as the Table 4 harness sizes them.
pub fn spot_check_sampling(base: &HwConfig, b: usize, l: usize, v: usize,
                           v_chunk: usize, seed: u64) -> SpotCheck {
    let v_chunk = v_chunk.clamp(1, v);
    let mut hw = base.clone();
    hw.v_chunk = v_chunk as u32;
    hw.vector_sram = ((2 * v_chunk + 4 * l) * 4) as u64;
    hw.int_sram = (5 * b * l * 4).max(1 << 14) as u64;

    let layout = SamplingLayout::new(b as u32, l as u32, v as u32,
                                     v_chunk as u32, 0);
    let prog = sampling_program(&layout, &vec![(l / 2).max(1) as u32; b]);
    let mut sim = CycleSim::new(hw.clone(), b * l * v + 64);
    let mut rng = SplitMix64::new(seed);
    // chunked fill to bound peak temp memory (large V × many positions)
    let mut off = 0usize;
    while off < b * l * v {
        let n = (1 << 20).min(b * l * v - off);
        let z = rng.normal_vec(n, 3.0);
        sim.hbm_store_f32(off, &z);
        off += n;
    }
    // token grid defaults to all-masked (mask_id 0 over zeroed Int SRAM)
    let rep = sim.run(&prog);
    let cycle_s = rep.cycles as f64 / hw.clock_hz;

    let asim = AnalyticalSim::new(hw, PrecisionConfig {
        sampling: SamplePrecision::Fp32,
        ..PrecisionConfig::dart_full_quant()
    });
    let analytical_s = asim.sampling_step(b as u64, l as u64, v as u64)
        .seconds;
    SpotCheck { analytical_s, cycle_s, cycles: rep.cycles }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::curve::Pct;

    fn calibrator(hw: HwConfig) -> Calibrator {
        let mut cfg = CalibConfig::serving_default(&[1, 4, 16]);
        cfg.samples_per_cell = 3;
        Calibrator::new(hw, ModelArch::llada_8b(), CacheMode::Dual, cfg)
    }

    #[test]
    fn profile_is_deterministic_and_complete() {
        let c = calibrator(HwConfig::dart_default());
        let a = c.profile("npu0");
        let b = c.profile("npu0");
        assert_eq!(a.points.len(), 3 * 4);
        assert_eq!(a.points.len(), b.points.len());
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.p50_total_s.to_bits(), y.p50_total_s.to_bits());
            assert_eq!(x.p95_first_s.to_bits(), y.p95_first_s.to_bits());
        }
    }

    #[test]
    fn curve_shape_is_physical() {
        let c = calibrator(HwConfig::dart_default()).profile("npu0");
        for p in &c.points {
            assert!(p.p50_total_s > 0.0);
            assert!(p.p95_total_s >= p.p50_total_s);
            assert!(p.p50_first_s <= p.p50_total_s);
            assert!(p.p95_first_s >= p.p50_first_s);
        }
        // bigger variant costs more at the same bucket (batch is not free)
        let t1 = c.total_s(1, 300, Pct::P50).unwrap();
        let t16 = c.total_s(16, 300, Pct::P50).unwrap();
        assert!(t16 > t1, "t16 {t16} vs t1 {t1}");
        // ... but is sublinear (the whole point of batching)
        assert!(t16 < 16.0 * t1, "t16 {t16} vs 16*t1 {}", 16.0 * t1);
        // longer sequences cost more at the same variant
        let short = c.total_s(4, 128, Pct::P50).unwrap();
        let long = c.total_s(4, 1500, Pct::P50).unwrap();
        assert!(long > short);
    }

    #[test]
    fn edge_point_is_slower_than_datacenter() {
        let dc = calibrator(HwConfig::dart_default()).profile("dc");
        let edge = calibrator(HwConfig::dart_edge()).profile("edge");
        let a = dc.total_s(4, 300, Pct::P50).unwrap();
        let b = edge.total_s(4, 300, Pct::P50).unwrap();
        assert!(b > a, "edge {b} vs dc {a}");
    }

    #[test]
    fn curve_roundtrips_through_text() {
        let c = calibrator(HwConfig::dart_edge()).profile("edge0");
        let back = LatencyCurve::from_text(&c.to_text()).unwrap();
        assert_eq!(back.device, "edge0");
        assert_eq!(back.points.len(), c.points.len());
        let a = c.measured_tokens_per_s().unwrap();
        let b = back.measured_tokens_per_s().unwrap();
        assert!(crate::util::rel_err(b, a) < 1e-6);
        assert_eq!(back.steps_per_block, c.steps_per_block);
        assert_eq!(back.expected_steps.to_bits(), c.expected_steps.to_bits());
    }

    #[test]
    fn adaptive_schedule_profiles_cheaper_than_fixed() {
        use crate::calib::curve::Pct;
        let mk = |schedule| {
            let mut cfg = CalibConfig::serving_default(&[1, 4]);
            cfg.samples_per_cell = 3;
            cfg.schedule = schedule;
            Calibrator::new(HwConfig::dart_default(), ModelArch::llada_8b(),
                            CacheMode::Dual, cfg).profile("npu0")
        };
        let fixed = mk(ScheduleSpec::Fixed);
        let slowfast = mk(ScheduleSpec::slowfast_default());
        // the fixed curve records the cap as its expectation; the
        // adaptive curve records fewer realized steps and cheaper cells
        assert!((fixed.expected_steps - 16.0).abs() < 1e-12);
        assert!(slowfast.expected_steps < fixed.expected_steps);
        let tf = fixed.total_s(4, 300, Pct::P50).unwrap();
        let ts = slowfast.total_s(4, 300, Pct::P50).unwrap();
        assert!(ts < tf, "slowfast {ts} vs fixed {tf}");
        // measured pace speeds up correspondingly
        assert!(slowfast.measured_tokens_per_s().unwrap()
                > fixed.measured_tokens_per_s().unwrap());
    }

    #[test]
    fn cached_profile_is_cheaper_and_off_is_bit_identical() {
        use crate::calib::curve::Pct;
        let mk = |feature_cache| {
            let mut cfg = CalibConfig::serving_default(&[1, 4]);
            cfg.samples_per_cell = 3;
            cfg.feature_cache = feature_cache;
            Calibrator::new(HwConfig::dart_default(), ModelArch::llada_8b(),
                            CacheMode::Dual, cfg).profile("npu0")
        };
        let off = mk(CachePolicySpec::Off);
        let degenerate = mk(CachePolicySpec::Interval {
            prompt_every: 1, response_every: 1 });
        // Off and the degenerate interval price every cell identically
        // to each other (both are the {1.0, 1.0} plan)
        assert_eq!(off.cache_hit_rate.to_bits(), 0.0f64.to_bits());
        assert_eq!(degenerate.cache_hit_rate.to_bits(), 0.0f64.to_bits());
        for (a, b) in off.points.iter().zip(&degenerate.points) {
            assert_eq!(a.p50_total_s.to_bits(), b.p50_total_s.to_bits());
            assert_eq!(a.p95_first_s.to_bits(), b.p95_first_s.to_bits());
        }
        // a caching profile records a warm hit rate and cheaper cells
        let warm = mk(CachePolicySpec::adaptive_default());
        assert!(warm.cache_hit_rate > 0.0 && warm.cache_hit_rate < 1.0,
                "hit rate {}", warm.cache_hit_rate);
        let tc = off.total_s(4, 300, Pct::P50).unwrap();
        let tw = warm.total_s(4, 300, Pct::P50).unwrap();
        assert!(tw < tc, "warm {tw} vs cold {tc}");
        assert!(warm.measured_tokens_per_s().unwrap()
                > off.measured_tokens_per_s().unwrap());
        // the recorded dimension survives the text roundtrip
        let back = LatencyCurve::from_text(&warm.to_text()).unwrap();
        assert_eq!(back.cache_hit_rate.to_bits(),
                   warm.cache_hit_rate.to_bits());
    }

    #[test]
    fn windowed_profile_is_cheaper_and_full_is_bit_identical() {
        use crate::calib::curve::Pct;
        let mk = |window| {
            let mut cfg = CalibConfig::serving_default(&[1, 4]);
            cfg.samples_per_cell = 3;
            cfg.window = window;
            Calibrator::new(HwConfig::dart_default(), ModelArch::llada_8b(),
                            CacheMode::Dual, cfg).profile("npu0")
        };
        let full = mk(WindowPolicySpec::Full);
        // a window wider than every profiled suffix is degenerate: the
        // serving fraction is exactly 1.0 and every cell prices
        // bit-identically to the full-suffix profile
        let wide = mk(WindowPolicySpec::Sliding { window: 1 << 20 });
        assert_eq!(full.window_frac.to_bits(), 1.0f64.to_bits());
        assert_eq!(wide.window_frac.to_bits(), 1.0f64.to_bits());
        for (a, b) in full.points.iter().zip(&wide.points) {
            assert_eq!(a.p50_total_s.to_bits(), b.p50_total_s.to_bits());
            assert_eq!(a.p95_first_s.to_bits(), b.p95_first_s.to_bits());
        }
        // a decay window records a narrowed fraction and cheaper cells
        let narrow = mk(WindowPolicySpec::decay_default());
        assert!(narrow.window_frac > 0.0 && narrow.window_frac < 1.0,
                "window frac {}", narrow.window_frac);
        let tf = full.total_s(4, 1500, Pct::P50).unwrap();
        let tn = narrow.total_s(4, 1500, Pct::P50).unwrap();
        assert!(tn < tf, "windowed {tn} vs full {tf}");
        assert!(narrow.measured_tokens_per_s().unwrap()
                > full.measured_tokens_per_s().unwrap());
        // the recorded dimension survives the text roundtrip
        let back = LatencyCurve::from_text(&narrow.to_text()).unwrap();
        assert_eq!(back.window_frac.to_bits(),
                   narrow.window_frac.to_bits());
    }

    #[test]
    fn spot_check_small_shape_agrees_roughly() {
        // a cheap sanity shape; the full Table 4 geometry lives in
        // rust/tests/cross_path.rs
        let s = spot_check_sampling(&HwConfig::dart_default(),
                                    1, 8, 16_384, 16_384, 11);
        assert!(s.analytical_s > 0.0 && s.cycle_s > 0.0);
        assert!(s.cycles > 0);
        assert!(s.rel_err() < 0.6, "rel err {}", s.rel_err());
    }
}
