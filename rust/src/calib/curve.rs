//! The measured per-device latency curve: batch variant × seq-len
//! bucket → total / first-block latency with percentile spread.
//!
//! A curve is produced by [`super::profiler::Calibrator`] (many jittered
//! workloads per cell through the analytical fast path, spot-checked
//! against the cycle simulator) and consumed by three layers: the
//! coordinator batcher's cost-based flush policy, the cluster
//! scheduler's percentile TTFT admission predictor, and the
//! `calibrate` CLI / `calib_policies` bench reports.
//!
//! Curves persist to a plain-text format (`# dart-latency-curve v1`)
//! in the same hand-rolled style as the cluster trace files, so a
//! device can be profiled once and the table replayed across serving
//! experiments.

use crate::report::Table;

/// Fraction of a step's model cost a feature-cache hit saves: a reused
/// step skips the transformer forward and restreams only the logit
/// buffer (the [`crate::sim::analytical::AnalyticalSim::run_cached`]
/// reuse-step accounting, folded to one scalar for curve rescaling).
pub const CACHE_SAVINGS: f64 = 0.75;

/// Relative per-step cost of serving at feature-cache hit rate `h`:
/// `1 − CACHE_SAVINGS·h`. Exactly 1.0 at `h = 0` (cache off).
pub fn cache_cost_frac(h: f64) -> f64 {
    1.0 - CACHE_SAVINGS * h.clamp(0.0, 1.0)
}

/// Which percentile of the measured spread a lookup should return.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pct {
    P50,
    P95,
}

/// One measured cell: a compiled batch variant at a total-sequence-length
/// bucket `[bucket_lo, bucket_hi)`.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub variant: usize,
    /// total sequence length (prompt + gen) bucket, inclusive low edge
    pub bucket_lo: u64,
    /// exclusive high edge
    pub bucket_hi: u64,
    /// generated tokens of the representative workload in this cell
    pub gen_tokens: u64,
    pub p50_total_s: f64,
    pub p95_total_s: f64,
    pub p50_first_s: f64,
    pub p95_first_s: f64,
    /// jittered workload samples behind the percentiles
    pub samples: u32,
}

impl CurvePoint {
    pub fn total_s(&self, pct: Pct) -> f64 {
        match pct {
            Pct::P50 => self.p50_total_s,
            Pct::P95 => self.p95_total_s,
        }
    }

    pub fn first_s(&self, pct: Pct) -> f64 {
        match pct {
            Pct::P50 => self.p50_first_s,
            Pct::P95 => self.p95_first_s,
        }
    }
}

/// Flattened lookup index over [`LatencyCurve::points`]: one entry per
/// distinct variant holding the contiguous points range, plus whether
/// that range's buckets are sorted and disjoint (the precondition for
/// the binary-search fast path in [`LatencyCurve::lookup_index`]).
/// Structure-only — it never caches latencies, so the replay
/// recalibrator's in-place percentile blending leaves it valid.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
struct CurveIndex {
    ranges: Vec<VariantRange>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct VariantRange {
    variant: usize,
    /// half-open range into `points`
    start: usize,
    end: usize,
    /// every bucket has `lo < hi` and buckets never overlap — when
    /// false (a degenerate hand-edited curve) lookups fall back to the
    /// reference linear scan over this range
    sorted_disjoint: bool,
}

impl CurveIndex {
    fn build(points: &[CurvePoint]) -> CurveIndex {
        let mut ranges = Vec::new();
        let mut i = 0;
        while i < points.len() {
            let v = points[i].variant;
            let start = i;
            while i < points.len() && points[i].variant == v {
                i += 1;
            }
            let run = &points[start..i];
            let sorted_disjoint = run.iter()
                .all(|p| p.bucket_lo < p.bucket_hi)
                && run.windows(2)
                    .all(|w| w[0].bucket_hi <= w[1].bucket_lo);
            ranges.push(VariantRange { variant: v, start, end: i,
                                       sorted_disjoint });
        }
        CurveIndex { ranges }
    }

    /// Cheap structural sanity check for debug builds: the ranges still
    /// tile `points` and name the variants at their start offsets. A
    /// full rebuild-and-compare lives in the property tests.
    fn covers(&self, points: &[CurvePoint]) -> bool {
        let mut expect = 0;
        for r in &self.ranges {
            if r.start != expect || r.end <= r.start || r.end > points.len()
                || points[r.start].variant != r.variant
            {
                return false;
            }
            expect = r.end;
        }
        expect == points.len()
    }
}

/// A device's full measured latency table.
#[derive(Clone, Debug)]
pub struct LatencyCurve {
    pub device: String,
    /// sorted by (variant, bucket_lo). Structural edits through this
    /// field (adding/removing/re-bucketing points) must be followed by
    /// [`Self::reindex`]; value edits (latencies, samples) need not.
    pub points: Vec<CurvePoint>,
    /// flattened lookup index mirroring the `points` structure
    index: CurveIndex,
    /// configured denoising-step cap the cells were profiled at
    pub steps_per_block: u64,
    /// *realized* steps per block the profiling billed — the
    /// expected-steps dimension: equal to `steps_per_block` for the
    /// fixed schedule, smaller for adaptive schedules
    /// ([`crate::schedule::ScheduleSpec::expected_steps`]). Consumers
    /// that serve under a different schedule rescale lookups by
    /// [`Self::step_scale`].
    pub expected_steps: f64,
    /// feature-cache hit rate the profiling billed — the warm/cold
    /// dimension: 0.0 for a cache-off (cold) profile, the
    /// [`crate::cache::CachePlan::hit_rate`] expectation for a cached
    /// one. Consumers serving at a different hit rate rescale lookups
    /// by [`Self::hit_scale`].
    pub cache_hit_rate: f64,
    /// mean active-suffix fraction the profiling billed — the
    /// suffix-window dimension: 1.0 for a full-suffix profile, the
    /// [`crate::window::WindowPolicySpec::serving_active_frac`]
    /// expectation for a windowed one. Consumers serving under a
    /// different window rescale lookups by [`Self::window_scale`].
    pub window_frac: f64,
}

impl LatencyCurve {
    pub fn new(device: &str, mut points: Vec<CurvePoint>) -> Self {
        points.sort_by_key(|p| (p.variant, p.bucket_lo));
        let index = CurveIndex::build(&points);
        LatencyCurve {
            device: device.to_string(),
            points,
            index,
            steps_per_block: 16,
            expected_steps: 16.0,
            cache_hit_rate: 0.0,
            window_frac: 1.0,
        }
    }

    /// Re-sort `points` and rebuild the flattened lookup index. Call
    /// after structurally mutating [`Self::points`] in place; curves
    /// built through [`Self::new`] / [`Self::from_text`] are already
    /// indexed.
    pub fn reindex(&mut self) {
        self.points.sort_by_key(|p| (p.variant, p.bucket_lo));
        self.index = CurveIndex::build(&self.points);
    }

    /// Record which schedule the curve was profiled under (the
    /// configured cap and the realized-steps expectation billed).
    pub fn with_schedule(mut self, steps_per_block: u64,
                         expected_steps: f64) -> Self {
        self.steps_per_block = steps_per_block.max(1);
        self.expected_steps = expected_steps
            .clamp(1.0, self.steps_per_block as f64);
        self
    }

    /// Record which feature-cache hit rate the curve was profiled at.
    pub fn with_cache(mut self, cache_hit_rate: f64) -> Self {
        self.cache_hit_rate = cache_hit_rate.clamp(0.0, 1.0);
        self
    }

    /// Record which mean active-suffix fraction the curve was profiled
    /// at (the suffix-window dimension).
    pub fn with_window(mut self, window_frac: f64) -> Self {
        self.window_frac = window_frac.clamp(0.0, 1.0);
        self
    }

    /// Latency multiplier for serving at active-suffix fraction
    /// `serving_frac` from a curve profiled at [`Self::window_frac`]:
    /// `window_cost_frac(serving) / window_cost_frac(profiled)`.
    /// Exactly 1.0 when the fractions match (`x / x`), so matched
    /// pricing — in particular the full-suffix default, 1.0 vs 1.0 —
    /// is untouched bit-for-bit.
    pub fn window_scale(&self, serving_frac: f64) -> f64 {
        crate::window::window_cost_frac(serving_frac)
            / crate::window::window_cost_frac(self.window_frac)
    }

    /// Latency multiplier for serving at feature-cache hit rate
    /// `serving_hit_rate` from a curve profiled at
    /// [`Self::cache_hit_rate`]:
    /// `cache_cost_frac(serving) / cache_cost_frac(profiled)`. Exactly
    /// 1.0 when the hit rates match (`x / x`), so matched pricing —
    /// in particular the cache-off default, 0.0 vs 0.0 — is untouched
    /// bit-for-bit.
    pub fn hit_scale(&self, serving_hit_rate: f64) -> f64 {
        cache_cost_frac(serving_hit_rate)
            / cache_cost_frac(self.cache_hit_rate)
    }

    /// Latency multiplier for serving at `serving_expected_steps`
    /// realized steps per block from a curve profiled at
    /// [`Self::expected_steps`] (per-step-linear approximation; exactly
    /// 1.0 when the schedules match, so matched pricing is untouched
    /// bit-for-bit).
    pub fn step_scale(&self, serving_expected_steps: f64) -> f64 {
        serving_expected_steps.max(1.0) / self.expected_steps.max(1.0)
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Distinct calibrated variants, ascending.
    pub fn variants(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.points.iter().map(|p| p.variant).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Midpoint of the middle bucket — the representative sequence
    /// length used when a caller needs one cost per variant.
    pub fn mid_seq_len(&self) -> u64 {
        let mut los: Vec<u64> = self.points.iter().map(|p| p.bucket_lo).collect();
        los.sort_unstable();
        los.dedup();
        if los.is_empty() {
            return 0;
        }
        let lo = los[los.len() / 2];
        let hi = self.points.iter()
            .find(|p| p.bucket_lo == lo)
            .map(|p| p.bucket_hi)
            .unwrap_or(lo + 1);
        (lo + hi) / 2
    }

    /// The cell covering (variant, seq_len): the smallest calibrated
    /// variant `>= variant` (or the largest when none fits — mirroring
    /// the batcher's pad-up rule), and the bucket containing `seq_len`.
    /// A `seq_len` no bucket covers — outside the profiled range, or in
    /// a gap of a sparse hand-trimmed curve — clamps to the bucket with
    /// the nearest edge (ties to the lower bucket), so a short request
    /// is never priced at a distant long-sequence cell.
    pub fn lookup(&self, variant: usize, seq_len: u64) -> Option<&CurvePoint> {
        self.lookup_index(variant, seq_len).map(|i| &self.points[i])
    }

    /// Index into [`Self::points`] of the cell [`Self::lookup`] resolves
    /// — the cell-attribution hook the replay recalibrator uses to route
    /// a measured observation back to the cell that priced it.
    pub fn lookup_index(&self, variant: usize, seq_len: u64)
                        -> Option<usize> {
        // this sits on the scheduler's per-arrival admission path and
        // inside batch pricing, so it resolves through the flattened
        // index: binary-search the variant range, then the bucket —
        // bit-identical to the reference scan (property-tested)
        debug_assert!(self.index.covers(&self.points),
                      "curve index is stale: points were structurally \
                       mutated without reindex()");
        let ranges = &self.index.ranges;
        // smallest calibrated variant >= requested (the batcher's
        // pad-up rule), clamping to the largest when none fits
        let ri = match ranges.binary_search_by(|r| r.variant.cmp(&variant)) {
            Ok(i) => i,
            Err(i) if i < ranges.len() => i,
            Err(_) => ranges.len().checked_sub(1)?,
        };
        let r = ranges[ri];
        if !r.sorted_disjoint {
            // degenerate bucket geometry: the reference scan's
            // first-match / first-minimum semantics are order-dependent,
            // so reproduce them literally over this variant's run
            return self.nearest_in_range(r.start, r.end, seq_len);
        }
        let pts = &self.points[r.start..r.end];
        // first bucket strictly above seq_len
        let up = pts.partition_point(|p| p.bucket_lo <= seq_len);
        if up > 0 && seq_len < pts[up - 1].bucket_hi {
            return Some(r.start + up - 1);
        }
        // gap or out-of-range: nearest edge wins; on a tie the linear
        // scan keeps the first (lower) bucket, so <= below
        match (up.checked_sub(1), (up < pts.len()).then_some(up)) {
            (None, None) => None,
            (Some(lo), None) => Some(r.start + lo),
            (None, Some(hi)) => Some(r.start + hi),
            (Some(lo), Some(hi)) => {
                let dl = seq_len
                    .saturating_sub(pts[lo].bucket_hi.saturating_sub(1));
                let dh = pts[hi].bucket_lo - seq_len;
                Some(r.start + if dl <= dh { lo } else { hi })
            }
        }
    }

    /// Reference implementation of [`Self::lookup_index`]: the original
    /// allocation-free linear scan over `points`. Kept as the oracle the
    /// flattened index is property-tested against — every result must
    /// match this, bit for bit.
    pub fn lookup_index_linear(&self, variant: usize, seq_len: u64)
                               -> Option<usize> {
        let v = self.points.iter().map(|p| p.variant)
            .find(|&pv| pv >= variant)
            .or_else(|| self.points.last().map(|p| p.variant))?;
        let mut best: Option<(usize, u64)> = None;
        for (i, p) in self.points.iter().enumerate()
            .filter(|(_, p)| p.variant == v)
        {
            if p.bucket_lo <= seq_len && seq_len < p.bucket_hi {
                return Some(i);
            }
            let dist = if seq_len < p.bucket_lo {
                p.bucket_lo - seq_len
            } else {
                seq_len.saturating_sub(p.bucket_hi.saturating_sub(1))
            };
            if best.map(|(_, d)| dist < d).unwrap_or(true) {
                best = Some((i, dist));
            }
        }
        best.map(|(i, _)| i)
    }

    /// The reference scan's bucket resolution over one variant's
    /// contiguous run: first in-bucket hit wins, otherwise the first
    /// point at the minimum edge distance.
    fn nearest_in_range(&self, start: usize, end: usize, seq_len: u64)
                        -> Option<usize> {
        let mut best: Option<(usize, u64)> = None;
        for (i, p) in self.points[start..end].iter().enumerate() {
            if p.bucket_lo <= seq_len && seq_len < p.bucket_hi {
                return Some(start + i);
            }
            let dist = if seq_len < p.bucket_lo {
                p.bucket_lo - seq_len
            } else {
                // saturating: a degenerate hand-edited row (hi == 0)
                // must not underflow on the admission path
                seq_len.saturating_sub(p.bucket_hi.saturating_sub(1))
            };
            if best.map(|(_, d)| dist < d).unwrap_or(true) {
                best = Some((start + i, dist));
            }
        }
        best.map(|(i, _)| i)
    }

    /// Measured total batch latency for serving `variant` lanes of
    /// `seq_len` total tokens.
    pub fn total_s(&self, variant: usize, seq_len: u64, pct: Pct) -> Option<f64> {
        self.lookup(variant, seq_len).map(|p| p.total_s(pct))
    }

    /// Measured first-block latency (the TTFT service component).
    pub fn first_block_s(&self, variant: usize, seq_len: u64, pct: Pct)
                         -> Option<f64> {
        self.lookup(variant, seq_len).map(|p| p.first_s(pct))
    }

    /// One measured cost per variant at a reference sequence length —
    /// the shape the batcher's [`crate::coordinator::batcher::CostModel`]
    /// consumes.
    pub fn variant_costs(&self, seq_len: u64, pct: Pct) -> Vec<(usize, f64)> {
        self.variants().into_iter()
            .filter_map(|v| self.total_s(v, seq_len, pct).map(|s| (v, s)))
            .collect()
    }

    /// Measured generated-tokens/s pace at the largest variant and the
    /// representative bucket — the scheduler's backlog→seconds factor
    /// (replacing the analytic tokens/s scalar).
    pub fn measured_tokens_per_s(&self) -> Option<f64> {
        let biggest = *self.variants().last()?;
        let p = self.lookup(biggest, self.mid_seq_len())?;
        Some((p.variant as u64 * p.gen_tokens) as f64
             / p.p50_total_s.max(1e-12))
    }

    // ---- persistence -----------------------------------------------------

    /// Serialize to the replay format: `# dart-latency-curve v4` header,
    /// a `device <name>` line, a `schedule <cap> <expected>` line (the
    /// expected-steps dimension), a `cache <hit_rate>` line (the
    /// warm/cold dimension), a `window <frac>` line (the suffix-window
    /// dimension), then one row per cell.
    pub fn to_text(&self) -> String {
        let mut s = String::from("# dart-latency-curve v4\n");
        s.push_str(&format!("device {}\n", self.device));
        // the schedule line is the expected-steps dimension; v1 files
        // without it parse as fixed-16 (the historical profile point)
        s.push_str(&format!("schedule {} {:.17e}\n",
                            self.steps_per_block, self.expected_steps));
        // the cache line is the feature-cache hit-rate dimension;
        // v1/v2 files without it parse as cold (hit rate 0.0)
        s.push_str(&format!("cache {:.17e}\n", self.cache_hit_rate));
        // the window line is the suffix-window dimension; v1–v3 files
        // without it parse as full-suffix (fraction 1.0)
        s.push_str(&format!("window {:.17e}\n", self.window_frac));
        s.push_str("# variant bucket_lo bucket_hi gen_tokens \
                    p50_total_s p95_total_s p50_first_s p95_first_s samples\n");
        for p in &self.points {
            // 17 significant digits: f64 values roundtrip exactly
            s.push_str(&format!(
                "{} {} {} {} {:.17e} {:.17e} {:.17e} {:.17e} {}\n",
                p.variant, p.bucket_lo, p.bucket_hi, p.gen_tokens,
                p.p50_total_s, p.p95_total_s, p.p50_first_s, p.p95_first_s,
                p.samples));
        }
        s
    }

    /// Parse the replay format (whitespace-separated, `#` comments
    /// ignored); rows are re-sorted. This is the replay half of the
    /// profile-once workflow: `calibrate --out` persists a curve via
    /// [`Self::to_text`], and a later serving run re-attaches the
    /// parsed copy (e.g. `serve-cluster --curve FILE`, or
    /// [`crate::cluster::ClusterTopology::attach_curve`] in code).
    ///
    /// ```
    /// use dart::calib::{LatencyCurve, Pct};
    ///
    /// let text = "device npu0\n\
    ///             1 96 256 128 0.010 0.012 0.003 0.004 5\n\
    ///             4 96 256 128 0.016 0.019 0.004 0.005 5\n";
    /// let curve = LatencyCurve::from_text(text).unwrap();
    /// assert_eq!(curve.device, "npu0");
    /// assert_eq!(curve.variants(), vec![1, 4]);
    /// // measured p50 batch latency for 4 lanes of ~128 total tokens
    /// let t = curve.total_s(4, 128, Pct::P50).unwrap();
    /// assert!((t - 0.016).abs() < 1e-12);
    /// // the text format round-trips exactly
    /// let back = LatencyCurve::from_text(&curve.to_text()).unwrap();
    /// assert_eq!(back.points.len(), curve.points.len());
    /// ```
    pub fn from_text(text: &str) -> Result<Self, String> {
        let mut device = String::from("unknown");
        let mut schedule: Option<(u64, f64)> = None;
        let mut cache_hit: Option<f64> = None;
        let mut window_frac: Option<f64> = None;
        let mut points = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("device ") {
                device = name.trim().to_string();
                continue;
            }
            if let Some(rest) = line.strip_prefix("schedule ") {
                let f: Vec<&str> = rest.split_whitespace().collect();
                let bad = || format!("curve line {}: bad schedule {line:?}",
                                     i + 1);
                if f.len() != 2 {
                    return Err(bad());
                }
                let cap: u64 = f[0].parse().map_err(|_| bad())?;
                let exp: f64 = f[1].parse().map_err(|_| bad())?;
                if cap == 0 || !exp.is_finite() || exp <= 0.0 {
                    return Err(bad());
                }
                schedule = Some((cap, exp));
                continue;
            }
            if let Some(rest) = line.strip_prefix("cache ") {
                let bad = || format!("curve line {}: bad cache {line:?}",
                                     i + 1);
                let h: f64 = rest.trim().parse().map_err(|_| bad())?;
                if !h.is_finite() || !(0.0..=1.0).contains(&h) {
                    return Err(bad());
                }
                cache_hit = Some(h);
                continue;
            }
            if let Some(rest) = line.strip_prefix("window ") {
                let bad = || format!("curve line {}: bad window {line:?}",
                                     i + 1);
                let w: f64 = rest.trim().parse().map_err(|_| bad())?;
                if !w.is_finite() || !(0.0..=1.0).contains(&w) {
                    return Err(bad());
                }
                window_frac = Some(w);
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 9 {
                return Err(format!("curve line {}: expected 9 fields, got {}",
                                   i + 1, f.len()));
            }
            let err = |what: &str| {
                format!("curve line {}: bad {what} {:?}", i + 1, line)
            };
            let fnum = |j: usize, what: &str| -> Result<f64, String> {
                let v: f64 = f[j].parse().map_err(|_| err(what))?;
                if v.is_finite() && v >= 0.0 {
                    Ok(v)
                } else {
                    Err(err(what))
                }
            };
            points.push(CurvePoint {
                variant: f[0].parse().map_err(|_| err("variant"))?,
                bucket_lo: f[1].parse().map_err(|_| err("bucket_lo"))?,
                bucket_hi: f[2].parse().map_err(|_| err("bucket_hi"))?,
                gen_tokens: f[3].parse().map_err(|_| err("gen_tokens"))?,
                p50_total_s: fnum(4, "p50_total_s")?,
                p95_total_s: fnum(5, "p95_total_s")?,
                p50_first_s: fnum(6, "p50_first_s")?,
                p95_first_s: fnum(7, "p95_first_s")?,
                samples: f[8].parse().map_err(|_| err("samples"))?,
            });
        }
        let mut curve = LatencyCurve::new(&device, points);
        if let Some((cap, exp)) = schedule {
            curve = curve.with_schedule(cap, exp);
        }
        if let Some(h) = cache_hit {
            curve = curve.with_cache(h);
        }
        if let Some(w) = window_frac {
            curve = curve.with_window(w);
        }
        Ok(curve)
    }

    /// Human-readable table for the `calibrate` CLI.
    pub fn render_table(&self) -> String {
        let mut t = Table::new(
            &format!("latency curve — {}", self.device),
            &["variant", "seq bucket", "gen", "p50 total",
              "p95 total", "p50 first", "p95 first", "n"]);
        for p in &self.points {
            t.row(&[p.variant.to_string(),
                    format!("[{}, {})", p.bucket_lo, p.bucket_hi),
                    p.gen_tokens.to_string(),
                    crate::stats::fmt_time(p.p50_total_s),
                    crate::stats::fmt_time(p.p95_total_s),
                    crate::stats::fmt_time(p.p50_first_s),
                    crate::stats::fmt_time(p.p95_first_s),
                    p.samples.to_string()]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(variant: usize, lo: u64, hi: u64, total: f64) -> CurvePoint {
        CurvePoint {
            variant,
            bucket_lo: lo,
            bucket_hi: hi,
            gen_tokens: (lo + hi) / 3,
            p50_total_s: total,
            p95_total_s: total * 1.2,
            p50_first_s: total / 4.0,
            p95_first_s: total / 3.0,
            samples: 5,
        }
    }

    fn curve() -> LatencyCurve {
        LatencyCurve::new("npu0", vec![
            point(1, 96, 256, 0.010),
            point(1, 256, 512, 0.020),
            point(4, 96, 256, 0.016),
            point(4, 256, 512, 0.032),
        ])
    }

    #[test]
    fn lookup_picks_variant_and_bucket() {
        let c = curve();
        assert_eq!(c.variants(), vec![1, 4]);
        let p = c.lookup(1, 128).unwrap();
        assert_eq!((p.variant, p.bucket_lo), (1, 96));
        // variant rounds up like the batcher's pad-up rule
        let p = c.lookup(3, 300).unwrap();
        assert_eq!((p.variant, p.bucket_lo), (4, 256));
        // above the largest variant clamps to it
        assert_eq!(c.lookup(9, 300).unwrap().variant, 4);
        // out-of-range seq lens clamp to the edge buckets
        assert_eq!(c.lookup(1, 10).unwrap().bucket_lo, 96);
        assert_eq!(c.lookup(1, 4096).unwrap().bucket_lo, 256);
    }

    #[test]
    fn lookup_in_a_bucket_gap_picks_the_nearest_edge() {
        // a sparse hand-trimmed curve: [96,256) and [1024,2048) with a
        // hole between — a 300-token request must price at the nearby
        // short bucket, not the distant long-sequence cell
        let c = LatencyCurve::new("npu0", vec![
            point(1, 96, 256, 0.010),
            point(1, 1024, 2048, 0.080),
        ]);
        assert_eq!(c.lookup(1, 300).unwrap().bucket_lo, 96);
        // near the far edge of the hole, the long bucket wins
        assert_eq!(c.lookup(1, 1000).unwrap().bucket_lo, 1024);
        // just below the crossover between the 255 and 1024 edges
        // (384 vs 385 away), the lower bucket still wins
        assert_eq!(c.lookup(1, 639).unwrap().bucket_lo, 96);
    }

    #[test]
    fn percentile_lookups() {
        let c = curve();
        let p50 = c.total_s(4, 128, Pct::P50).unwrap();
        let p95 = c.total_s(4, 128, Pct::P95).unwrap();
        assert!(p95 > p50);
        let f50 = c.first_block_s(4, 128, Pct::P50).unwrap();
        assert!(f50 < p50);
    }

    #[test]
    fn variant_costs_cover_every_variant() {
        let c = curve();
        let costs = c.variant_costs(300, Pct::P50);
        assert_eq!(costs.len(), 2);
        assert_eq!(costs[0].0, 1);
        assert!((costs[0].1 - 0.020).abs() < 1e-12);
        assert!((costs[1].1 - 0.032).abs() < 1e-12);
    }

    #[test]
    fn measured_pace_is_positive() {
        let c = curve();
        let tps = c.measured_tokens_per_s().unwrap();
        assert!(tps > 0.0);
        let empty = LatencyCurve::new("x", vec![]);
        assert!(empty.measured_tokens_per_s().is_none());
        assert!(empty.is_empty());
    }

    #[test]
    fn text_roundtrip() {
        let c = curve();
        let text = c.to_text();
        let back = LatencyCurve::from_text(&text).unwrap();
        assert_eq!(back.device, "npu0");
        assert_eq!(back.points.len(), c.points.len());
        for (a, b) in c.points.iter().zip(&back.points) {
            assert_eq!(a.variant, b.variant);
            assert_eq!(a.bucket_lo, b.bucket_lo);
            assert_eq!(a.bucket_hi, b.bucket_hi);
            assert_eq!(a.gen_tokens, b.gen_tokens);
            assert!((a.p50_total_s - b.p50_total_s).abs() < 1e-15);
            assert!((a.p95_first_s - b.p95_first_s).abs() < 1e-15);
            assert_eq!(a.samples, b.samples);
        }
    }

    #[test]
    fn malformed_curve_rejected() {
        assert!(LatencyCurve::from_text("1 2 3").is_err());
        assert!(LatencyCurve::from_text("x 96 256 64 1 1 1 1 5").is_err());
        assert!(LatencyCurve::from_text("1 96 256 64 nan 1 1 1 5").is_err());
        assert!(LatencyCurve::from_text("# only comments\n").unwrap().is_empty());
        // malformed schedule metadata is an error, not a silent default
        assert!(LatencyCurve::from_text("schedule 16\n").is_err());
        assert!(LatencyCurve::from_text("schedule 0 16.0\n").is_err());
        assert!(LatencyCurve::from_text("schedule 16 nan\n").is_err());
        // ... and so is malformed cache metadata
        assert!(LatencyCurve::from_text("cache x\n").is_err());
        assert!(LatencyCurve::from_text("cache 1.5\n").is_err());
        assert!(LatencyCurve::from_text("cache -0.1\n").is_err());
        assert!(LatencyCurve::from_text("cache nan\n").is_err());
        // ... and malformed window metadata
        assert!(LatencyCurve::from_text("window x\n").is_err());
        assert!(LatencyCurve::from_text("window 1.5\n").is_err());
        assert!(LatencyCurve::from_text("window -0.1\n").is_err());
        assert!(LatencyCurve::from_text("window nan\n").is_err());
    }

    #[test]
    fn window_dimension_roundtrips_and_defaults() {
        // v1–v3 files (no window line) parse as full-suffix (1.0)
        let v3 = LatencyCurve::from_text(
            "device npu0\nschedule 16 9.25\ncache 0.25\n\
             1 96 256 128 0.01 0.012 0.003 0.004 5\n").unwrap();
        assert_eq!(v3.window_frac.to_bits(), 1.0f64.to_bits());
        // a recorded fraction survives the text roundtrip bit-exactly
        let c = curve().with_window(0.3125);
        let back = LatencyCurve::from_text(&c.to_text()).unwrap();
        assert_eq!(back.window_frac.to_bits(), 0.3125f64.to_bits());
        // window_scale: matched fractions price untouched bit-for-bit
        assert_eq!(back.window_scale(0.3125).to_bits(), 1.0f64.to_bits());
        assert_eq!(v3.window_scale(1.0).to_bits(), 1.0f64.to_bits());
        // serving narrower than profiled is cheaper, wider is dearer
        assert!(back.window_scale(0.1) < 1.0);
        assert!(back.window_scale(1.0) > 1.0);
        // a full-suffix curve priced for windowed serving scales by
        // the window cost fraction
        let narrow = v3.window_scale(0.5);
        assert!((narrow - crate::window::window_cost_frac(0.5)).abs()
                < 1e-15);
        // with_window clamps into [0, 1]
        assert_eq!(curve().with_window(7.0).window_frac, 1.0);
        assert_eq!(curve().with_window(-7.0).window_frac, 0.0);
    }

    #[test]
    fn schedule_dimension_roundtrips_and_defaults() {
        // v1 files (no schedule line) parse as the historical fixed-16
        // profile point
        let v1 = LatencyCurve::from_text(
            "device npu0\n1 96 256 128 0.01 0.012 0.003 0.004 5\n").unwrap();
        assert_eq!(v1.steps_per_block, 16);
        assert!((v1.expected_steps - 16.0).abs() < 1e-12);
        // a recorded schedule survives the text roundtrip bit-exactly
        let c = curve().with_schedule(16, 9.25);
        let back = LatencyCurve::from_text(&c.to_text()).unwrap();
        assert_eq!(back.steps_per_block, 16);
        assert_eq!(back.expected_steps.to_bits(), 9.25f64.to_bits());
        // step_scale: matched schedules price untouched, mismatched
        // rescale per-step-linearly
        assert_eq!(back.step_scale(9.25).to_bits(), 1.0f64.to_bits());
        assert!((back.step_scale(18.5) - 2.0).abs() < 1e-12);
        assert!(back.step_scale(4.0) < 1.0);
        // with_schedule clamps the expectation into [1, cap]
        let clamped = curve().with_schedule(8, 99.0);
        assert!((clamped.expected_steps - 8.0).abs() < 1e-12);
    }

    #[test]
    fn cache_dimension_roundtrips_and_defaults() {
        // v1/v2 files (no cache line) parse as cold (hit rate 0.0)
        let v2 = LatencyCurve::from_text(
            "device npu0\nschedule 16 9.25\n\
             1 96 256 128 0.01 0.012 0.003 0.004 5\n").unwrap();
        assert_eq!(v2.cache_hit_rate.to_bits(), 0.0f64.to_bits());
        // a recorded hit rate survives the text roundtrip bit-exactly
        let c = curve().with_cache(0.4375);
        let back = LatencyCurve::from_text(&c.to_text()).unwrap();
        assert_eq!(back.cache_hit_rate.to_bits(), 0.4375f64.to_bits());
        // hit_scale: matched hit rates price untouched bit-for-bit
        assert_eq!(back.hit_scale(0.4375).to_bits(), 1.0f64.to_bits());
        assert_eq!(v2.hit_scale(0.0).to_bits(), 1.0f64.to_bits());
        // serving warmer than profiled is cheaper, colder is dearer
        assert!(back.hit_scale(0.8) < 1.0);
        assert!(back.hit_scale(0.0) > 1.0);
        // a cold curve priced for warm serving scales by cost_frac
        let warm = v2.hit_scale(0.5);
        assert!((warm - cache_cost_frac(0.5)).abs() < 1e-15);
        // with_cache clamps into [0, 1]
        assert_eq!(curve().with_cache(7.0).cache_hit_rate, 1.0);
        assert_eq!(curve().with_cache(-7.0).cache_hit_rate, 0.0);
    }

    #[test]
    fn render_mentions_every_variant() {
        let r = curve().render_table();
        assert!(r.contains("npu0"));
        assert!(r.contains("p95 total"));
    }

    /// Draw one random-but-physical curve: random variant set, random
    /// bucket edges (possibly sparse, with gaps), random f64 latencies,
    /// and — half the time — a fractional recorded schedule.
    fn random_curve(rng: &mut crate::util::SplitMix64) -> LatencyCurve {
        let n_variants = rng.range(1, 4) as usize;
        let mut variants: Vec<usize> =
            (0..n_variants).map(|_| rng.range(1, 32) as usize).collect();
        variants.sort_unstable();
        variants.dedup();
        let n_buckets = rng.range(1, 5) as usize;
        let mut edges: Vec<u64> = Vec::new();
        let mut lo = rng.range(8, 256);
        for _ in 0..n_buckets {
            // occasional gap between buckets → sparse curves
            let gap = if rng.next_f64() < 0.3 { rng.range(1, 512) } else { 0 };
            let hi = lo + gap + rng.range(16, 1024);
            edges.push(lo + gap);
            edges.push(hi);
            lo = hi;
        }
        let mut points = Vec::new();
        for &v in &variants {
            for b in 0..n_buckets {
                let (blo, bhi) = (edges[2 * b], edges[2 * b + 1]);
                let p50 = rng.next_f64() * 0.1 + 1e-6;
                let first = p50 * (0.1 + 0.8 * rng.next_f64());
                points.push(CurvePoint {
                    variant: v,
                    bucket_lo: blo,
                    bucket_hi: bhi,
                    gen_tokens: rng.range(1, bhi),
                    p50_total_s: p50,
                    p95_total_s: p50 * (1.0 + rng.next_f64()),
                    p50_first_s: first,
                    p95_first_s: first * (1.0 + rng.next_f64()),
                    samples: rng.range(1, 64) as u32,
                });
            }
        }
        let mut c = LatencyCurve::new(&format!("dev{}", rng.range(0, 100)),
                                      points);
        if rng.next_f64() < 0.5 {
            let cap = rng.range(2, 33);
            c = c.with_schedule(cap, 1.0 + rng.next_f64() * (cap - 1) as f64);
        }
        if rng.next_f64() < 0.5 {
            // half the curves carry a warm (cached) profile point
            c = c.with_cache(rng.next_f64());
        }
        if rng.next_f64() < 0.5 {
            // half the curves carry a windowed (narrowed) profile point
            c = c.with_window(0.05 + 0.95 * rng.next_f64());
        }
        c
    }

    #[test]
    fn prop_text_format_emit_parse_emit_is_byte_identical() {
        // the replay-format contract: to_text ∘ from_text ∘ to_text is
        // the identity on bytes (17-sig-digit floats round-trip f64
        // exactly, rows re-sort stably, schedule metadata survives)
        crate::stats::prop_check(
            "curve text emit→parse→emit", 64,
            random_curve,
            |c| {
                let text1 = c.to_text();
                let back = LatencyCurve::from_text(&text1)
                    .map_err(|e| format!("parse failed: {e}"))?;
                let text2 = back.to_text();
                if text1 != text2 {
                    return Err(format!(
                        "round-trip drifted:\n--- emitted\n{text1}\n--- \
                         re-emitted\n{text2}"));
                }
                if back.expected_steps.to_bits() != c.expected_steps.to_bits()
                    || back.steps_per_block != c.steps_per_block
                {
                    return Err("schedule dimension drifted".into());
                }
                if back.cache_hit_rate.to_bits() != c.cache_hit_rate.to_bits()
                {
                    return Err("cache dimension drifted".into());
                }
                if back.window_frac.to_bits() != c.window_frac.to_bits() {
                    return Err("window dimension drifted".into());
                }
                Ok(())
            });
    }

    #[test]
    fn prop_v1_files_parse_and_reemit_stably() {
        // v1 files carry no schedule line; parsing defaults to the
        // historical fixed-16 point and the *re-emitted* v2 text then
        // round-trips byte-identically forever after
        crate::stats::prop_check(
            "curve text v1 back-compat", 32,
            random_curve,
            |c| {
                // hand-build the v1 serialization: header + device +
                // rows, no schedule line
                let mut v1 = String::from("# dart-latency-curve v1\n");
                v1.push_str(&format!("device {}\n", c.device));
                for p in &c.points {
                    v1.push_str(&format!(
                        "{} {} {} {} {:.17e} {:.17e} {:.17e} {:.17e} {}\n",
                        p.variant, p.bucket_lo, p.bucket_hi, p.gen_tokens,
                        p.p50_total_s, p.p95_total_s, p.p50_first_s,
                        p.p95_first_s, p.samples));
                }
                let parsed = LatencyCurve::from_text(&v1)
                    .map_err(|e| format!("v1 parse failed: {e}"))?;
                if parsed.steps_per_block != 16
                    || parsed.expected_steps.to_bits() != 16.0f64.to_bits()
                {
                    return Err("v1 default schedule wrong".into());
                }
                if parsed.cache_hit_rate.to_bits() != 0.0f64.to_bits() {
                    return Err("v1 default cache dimension wrong".into());
                }
                if parsed.window_frac.to_bits() != 1.0f64.to_bits() {
                    return Err("v1 default window dimension wrong".into());
                }
                // a v2 file (schedule line, no cache line) also parses
                // cold and upgrades stably
                let mut v2 = String::from("# dart-latency-curve v2\n");
                v2.push_str(&format!("device {}\n", c.device));
                v2.push_str(&format!("schedule {} {:.17e}\n",
                                     c.steps_per_block, c.expected_steps));
                let pv2 = LatencyCurve::from_text(&v2)
                    .map_err(|e| format!("v2 parse failed: {e}"))?;
                if pv2.cache_hit_rate.to_bits() != 0.0f64.to_bits() {
                    return Err("v2 default cache dimension wrong".into());
                }
                if pv2.window_frac.to_bits() != 1.0f64.to_bits() {
                    return Err("v2 default window dimension wrong".into());
                }
                if parsed.points.len() != c.points.len() {
                    return Err("v1 row count drifted".into());
                }
                for (a, b) in c.points.iter().zip(&parsed.points) {
                    if a.p50_total_s.to_bits() != b.p50_total_s.to_bits()
                        || a.p95_first_s.to_bits() != b.p95_first_s.to_bits()
                    {
                        return Err("v1 float drifted".into());
                    }
                }
                let text1 = parsed.to_text();
                let text2 = LatencyCurve::from_text(&text1)
                    .map_err(|e| format!("v2 reparse failed: {e}"))?
                    .to_text();
                if text1 != text2 {
                    return Err("v1→v2 upgrade not stable".into());
                }
                Ok(())
            });
    }

    #[test]
    fn prop_sparse_curves_clamp_lookups_to_the_nearest_edge() {
        // every lookup on a random (possibly gappy) curve must resolve
        // to *some* cell of the resolved variant, and in-bucket hits
        // must resolve exactly
        crate::stats::prop_check(
            "sparse-curve lookup clamp", 64,
            |rng| {
                let c = random_curve(rng);
                let probe = rng.range(0, 4096);
                let v = rng.range(0, 40) as usize;
                (c, v, probe)
            },
            |(c, v, probe)| {
                let Some(i) = c.lookup_index(*v, *probe) else {
                    return Err("lookup on a non-empty curve failed".into());
                };
                let p = &c.points[i];
                let in_bucket =
                    p.bucket_lo <= *probe && *probe < p.bucket_hi;
                if !in_bucket {
                    // clamped: no other cell of the same variant may be
                    // strictly nearer
                    let dist = |q: &CurvePoint| if *probe < q.bucket_lo {
                        q.bucket_lo - *probe
                    } else {
                        probe.saturating_sub(q.bucket_hi.saturating_sub(1))
                    };
                    let d = dist(p);
                    for q in c.points.iter()
                        .filter(|q| q.variant == p.variant)
                    {
                        if dist(q) < d {
                            return Err(format!(
                                "clamp missed a nearer bucket: {} vs {}",
                                q.bucket_lo, p.bucket_lo));
                        }
                    }
                }
                // lookup and lookup_index agree
                let via_ref = c.lookup(*v, *probe).unwrap();
                if via_ref.bucket_lo != p.bucket_lo
                    || via_ref.variant != p.variant
                {
                    return Err("lookup/lookup_index disagree".into());
                }
                Ok(())
            });
    }

    /// Exhaustive flattened-vs-linear comparison over every interesting
    /// probe of one curve: all bucket edges ±1, deep inside gaps, and
    /// far outside the profiled range, for variants from 0 (below the
    /// ladder) past the largest calibrated one.
    fn assert_lookup_matches_linear(c: &LatencyCurve, ctx: &str)
                                    -> Result<(), String> {
        let mut probes: Vec<u64> = vec![0, 1, u64::MAX / 2, u64::MAX];
        for p in &c.points {
            for edge in [p.bucket_lo, p.bucket_hi, p.gen_tokens] {
                probes.extend([edge.saturating_sub(1), edge,
                               edge.saturating_add(1)]);
            }
        }
        let mut variants: Vec<usize> = vec![0, 1, usize::MAX];
        for p in &c.points {
            variants.extend([p.variant.saturating_sub(1), p.variant,
                             p.variant + 1]);
        }
        for &v in &variants {
            for &s in &probes {
                let flat = c.lookup_index(v, s);
                let lin = c.lookup_index_linear(v, s);
                if flat != lin {
                    return Err(format!(
                        "{ctx}: lookup_index({v}, {s}) = {flat:?} but \
                         linear scan says {lin:?}"));
                }
            }
        }
        Ok(())
    }

    #[test]
    fn prop_flattened_lookup_is_bit_identical_to_linear_scan() {
        // the flattened-index equivalence gate: on random sparse curves
        // (bucket gaps force the nearest-edge clamp) the indexed lookup
        // must resolve the exact cell the reference linear scan does —
        // including after a v4 text round-trip, which rebuilds the
        // index from parsed points
        crate::stats::prop_check(
            "flattened lookup == linear scan", 64,
            random_curve,
            |c| {
                assert_lookup_matches_linear(c, "generated curve")?;
                let parsed = LatencyCurve::from_text(&c.to_text())
                    .map_err(|e| format!("round-trip parse failed: {e}"))?;
                assert_lookup_matches_linear(&parsed, "parsed v4 curve")
            });
    }

    #[test]
    fn flattened_lookup_matches_linear_on_v1_parsed_curves() {
        // v1 files (bare 9-field rows, no header lines) build their
        // index through the same from_text funnel
        let v1 = "\
            1 96 256 64 0.010 0.012 0.002 0.003 5\n\
            1 512 1024 64 0.020 0.024 0.004 0.005 5\n\
            4 96 256 64 0.016 0.019 0.003 0.004 5\n\
            4 512 1024 64 0.032 0.038 0.006 0.008 5\n";
        let c = LatencyCurve::from_text(v1).unwrap();
        assert_lookup_matches_linear(&c, "v1 curve").unwrap();
    }

    #[test]
    fn degenerate_buckets_fall_back_to_the_reference_scan() {
        // overlapping, inverted and empty (hi <= lo) buckets defeat the
        // binary-search preconditions; the index must detect that per
        // variant and reproduce the order-dependent reference semantics
        let p = |v: usize, lo: u64, hi: u64| CurvePoint {
            variant: v, bucket_lo: lo, bucket_hi: hi, gen_tokens: 64,
            p50_total_s: 0.01, p95_total_s: 0.012,
            p50_first_s: 0.002, p95_first_s: 0.003, samples: 5,
        };
        let c = LatencyCurve::new("dgn", vec![
            p(1, 96, 512),   // overlaps the next bucket
            p(1, 256, 384),
            p(1, 700, 700),  // empty
            p(2, 100, 0),    // inverted (hi < lo)
            p(2, 50, 60),    // well-formed variant mixed in
        ]);
        assert_lookup_matches_linear(&c, "degenerate curve").unwrap();
        // the well-formed variant still resolves in-bucket hits
        assert_eq!(c.lookup(2, 55).unwrap().bucket_lo, 50);
    }

    #[test]
    fn reindex_restores_lookup_after_structural_mutation() {
        let mut c = curve();
        // graft a new cell through the pub field (what a hand-edit or
        // an external tool would do), then reindex
        c.points.push(CurvePoint {
            variant: 8, bucket_lo: 96, bucket_hi: 256, gen_tokens: 64,
            p50_total_s: 0.05, p95_total_s: 0.06,
            p50_first_s: 0.01, p95_first_s: 0.012, samples: 5,
        });
        c.reindex();
        assert_eq!(c.lookup(8, 128).unwrap().variant, 8);
        assert_lookup_matches_linear(&c, "reindexed curve").unwrap();
    }
}
