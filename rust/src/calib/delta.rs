//! Curve-diff utilities: how far apart two [`LatencyCurve`]s price the
//! same cells.
//!
//! [`CurveDelta`] is the common vocabulary of the replay loop: the
//! recalibration fixed-point test asserts a **zero** delta
//! (recalibrating from a curve's own observations must not move it, bit
//! for bit), the `serve-cluster --recalibrate` report and the
//! `recalib_loop` bench print how far measured serving pulled each
//! device's table, and `rust/tests/recalib_convergence.rs` gates the
//! monotone-shrink property on the max cell error.

use crate::report::Table;

use super::curve::LatencyCurve;

/// Per-cell pricing movement between two curves sharing a cell
/// geometry. `rel` is the **max** absolute relative change across the
/// four percentile fields (p50/p95 × total/first), so a cell only
/// reads as unchanged when every quantity it prices is unchanged.
#[derive(Clone, Copy, Debug)]
pub struct CellDelta {
    pub variant: usize,
    pub bucket_lo: u64,
    pub bucket_hi: u64,
    /// max |after − before| / max(|before|, ε) over the four fields
    pub rel: f64,
}

/// The full diff between a `before` and an `after` curve.
#[derive(Clone, Debug, Default)]
pub struct CurveDelta {
    /// one entry per (variant, bucket) cell present in both curves, in
    /// the curves' sorted point order
    pub cells: Vec<CellDelta>,
    /// cells present in only one of the two curves (geometry drift —
    /// zero whenever `after` came from recalibrating `before`)
    pub mismatched_cells: usize,
    /// after.expected_steps − before.expected_steps
    pub expected_steps_delta: f64,
}

impl CurveDelta {
    /// Diff `after` against `before`, matching cells by exact
    /// (variant, bucket_lo, bucket_hi).
    pub fn between(before: &LatencyCurve, after: &LatencyCurve) -> Self {
        let mut cells = Vec::new();
        let mut matched_after = 0usize;
        for b in &before.points {
            let Some(a) = after.points.iter().find(|a| {
                a.variant == b.variant
                    && a.bucket_lo == b.bucket_lo
                    && a.bucket_hi == b.bucket_hi
            }) else {
                continue;
            };
            matched_after += 1;
            let rel = [
                (b.p50_total_s, a.p50_total_s),
                (b.p95_total_s, a.p95_total_s),
                (b.p50_first_s, a.p50_first_s),
                (b.p95_first_s, a.p95_first_s),
            ]
            .iter()
            .map(|&(x, y)| crate::util::rel_err(y, x))
            .fold(0.0f64, f64::max);
            cells.push(CellDelta {
                variant: b.variant,
                bucket_lo: b.bucket_lo,
                bucket_hi: b.bucket_hi,
                rel,
            });
        }
        let mismatched = (before.points.len() - cells.len())
            + after.points.len().saturating_sub(matched_after);
        CurveDelta {
            cells,
            mismatched_cells: mismatched,
            expected_steps_delta: after.expected_steps
                - before.expected_steps,
        }
    }

    /// Largest per-cell relative movement (0.0 on an empty diff).
    pub fn max_rel(&self) -> f64 {
        crate::stats::max_mean(self.cells.iter().map(|c| c.rel)).0
    }

    /// Mean per-cell relative movement (0.0 on an empty diff).
    pub fn mean_rel(&self) -> f64 {
        crate::stats::max_mean(self.cells.iter().map(|c| c.rel)).1
    }

    /// True when the two curves price identically: every matched cell
    /// moved by exactly 0.0, no cell exists in only one curve, and the
    /// expected-steps dimension is unchanged — the recalibration
    /// fixed-point predicate.
    pub fn is_zero(&self) -> bool {
        self.mismatched_cells == 0
            && self.expected_steps_delta == 0.0
            && self.cells.iter().all(|c| c.rel == 0.0)
    }

    /// Human-readable per-cell table (debugging surface; the CLI's
    /// per-device summary is
    /// [`crate::replay::render_pricing_report`], which reports only
    /// [`Self::max_rel`]).
    pub fn render_table(&self, title: &str) -> String {
        let mut t = Table::new(title,
                               &["variant", "seq bucket", "moved"]);
        for c in &self.cells {
            t.row(&[c.variant.to_string(),
                    format!("[{}, {})", c.bucket_lo, c.bucket_hi),
                    crate::report::pct(c.rel)]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::curve::CurvePoint;

    fn point(variant: usize, lo: u64, hi: u64, total: f64) -> CurvePoint {
        CurvePoint {
            variant,
            bucket_lo: lo,
            bucket_hi: hi,
            gen_tokens: (lo + hi) / 3,
            p50_total_s: total,
            p95_total_s: total * 1.2,
            p50_first_s: total / 4.0,
            p95_first_s: total / 3.0,
            samples: 5,
        }
    }

    fn curve() -> LatencyCurve {
        LatencyCurve::new("npu0", vec![
            point(1, 96, 256, 0.010),
            point(4, 96, 256, 0.016),
        ])
    }

    #[test]
    fn identical_curves_diff_to_zero() {
        let c = curve();
        let d = CurveDelta::between(&c, &c.clone());
        assert_eq!(d.cells.len(), 2);
        assert_eq!(d.mismatched_cells, 0);
        assert!(d.is_zero());
        assert_eq!(d.max_rel(), 0.0);
        assert_eq!(d.mean_rel(), 0.0);
        assert_eq!(d.expected_steps_delta, 0.0);
    }

    #[test]
    fn moved_cell_is_measured_on_its_worst_field() {
        let a = curve();
        let mut b = curve();
        // move only the p95_first of one cell by +50%
        b.points[1].p95_first_s *= 1.5;
        let d = CurveDelta::between(&a, &b);
        assert!(!d.is_zero());
        assert!((d.max_rel() - 0.5).abs() < 1e-9, "max {}", d.max_rel());
        // the untouched cell reads exactly zero
        assert_eq!(d.cells[0].rel, 0.0);
        assert!((d.mean_rel() - 0.25).abs() < 1e-9);
        let r = d.render_table("delta");
        assert!(r.contains("[96, 256)"));
    }

    #[test]
    fn geometry_drift_counts_mismatched_cells() {
        let a = curve();
        let b = LatencyCurve::new("npu0", vec![
            point(1, 96, 256, 0.010),
            point(8, 96, 256, 0.020), // variant 4 gone, 8 appeared
        ]);
        let d = CurveDelta::between(&a, &b);
        assert_eq!(d.cells.len(), 1);
        assert_eq!(d.mismatched_cells, 2);
        assert!(!d.is_zero());
    }

    #[test]
    fn expected_steps_movement_breaks_the_fixed_point() {
        let a = curve().with_schedule(16, 16.0);
        let b = curve().with_schedule(16, 9.25);
        let d = CurveDelta::between(&a, &b);
        assert_eq!(d.max_rel(), 0.0);
        assert!(!d.is_zero());
        assert!((d.expected_steps_delta + 6.75).abs() < 1e-12);
    }
}
