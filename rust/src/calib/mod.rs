//! Device calibration: measured batch-variant latency curves.
//!
//! The paper's sampling-dominated latency profile makes per-batch cost
//! highly non-linear in batch size and sequence length, so scheduling
//! decisions driven by analytic scalars (a single tokens/s estimate, a
//! static exact-fill-vs-pad-up rule) leave goodput on the table. This
//! subsystem profiles every compiled batch variant of a device through
//! the tri-path simulator and distills the measurements into a
//! persistable per-device [`LatencyCurve`] (latency vs batch variant ×
//! seq-len bucket, with p50/p95 spread). The curves then drive:
//!
//! * the coordinator batcher's **cost-based flush policy**
//!   ([`crate::coordinator::batcher::CostModel`]) — exact-fill vs
//!   pad-up decided by measured variant latencies plus expected-arrival
//!   wait cost;
//! * the cluster scheduler's **percentile TTFT admission predictor**
//!   — measured p95 first-block latency instead of the calibrated
//!   tokens/s scalar;
//! * the `calibrate` CLI subcommand and the `calib_policies` bench,
//!   which quantify the shed-rate / padding-waste deltas of
//!   curve-driven vs static policies.
//!
//! The analytical simulator is the profiling fast path;
//! [`spot_check_sampling`] cross-validates it against the
//! cycle-accurate simulator at a matched sampling shape (the Table 4
//! methodology, callable in-process).
//!
//! Curves are profiled once through the analytical path, but they do
//! not have to stay that way: the [`crate::replay`] subsystem drains
//! *measured* serving observations back into the table
//! ([`crate::replay::Recalibrator`]), and [`CurveDelta`] is the diff
//! vocabulary both the CLI report and the convergence test net use to
//! say how far (or, at the fixed point, that not at all) a replay round
//! moved the pricing.
//!
//! Curves carry an **expected-steps dimension**
//! ([`LatencyCurve::expected_steps`]): profiling bills the configured
//! denoising schedule's expected *realized* steps per block
//! ([`crate::schedule::ScheduleSpec::expected_steps`]) rather than the
//! configured cap, and a curve replayed under a different schedule
//! rescales per-step-linearly via [`LatencyCurve::step_scale`] — so
//! admission and batching price variable-step requests honestly.
//!
//! They also carry a **feature-cache hit-rate dimension**
//! ([`LatencyCurve::cache_hit_rate`]): profiling bills the configured
//! cross-step feature-cache policy's expected refresh/reuse mix
//! ([`crate::cache::CachePlan`]) and records the hit-rate expectation,
//! and a curve replayed at a different hit rate rescales via
//! [`LatencyCurve::hit_scale`] — so admission can price warm
//! steady-state serving against cold first blocks from one profile.
//!
//! And a **suffix-window dimension** ([`LatencyCurve::window_frac`]):
//! profiling bills the configured suffix-window policy's per-block
//! active-suffix fractions
//! ([`crate::window::WindowPolicySpec::active_suffix_len`], the S12
//! closed form) and records the serving expectation, and a curve
//! replayed under a different window rescales via
//! [`LatencyCurve::window_scale`] — so long-form admission prices
//! windowed serving honestly from a chat-profiled curve (text format
//! v4; v1–v3 files parse as full-suffix).

pub mod curve;
pub mod delta;
pub mod profiler;

pub use curve::{cache_cost_frac, CurvePoint, LatencyCurve, Pct,
                CACHE_SAVINGS};
pub use delta::{CellDelta, CurveDelta};
pub use profiler::{spot_check_sampling, CalibConfig, Calibrator, SpotCheck};
