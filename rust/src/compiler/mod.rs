//! The DART compiler: model graphs → ISA instruction streams
//! (paper §3.1.3 "PyTorch-to-ISA compiler").
//!
//! Emits the programs the cycle-accurate simulator executes:
//!
//! * [`gemm_program`] / [`softmax_program`] / [`flash_attention_program`]
//!   — the Table 3 compound validation sequences (the FlashAttention
//!   program is the paper's 6-GEMM layer schedule at d=64, H=2);
//! * [`sampling_program`] — the complete Algorithm 2 intra-block
//!   sampling flow across the four phases and three SRAM domains, with
//!   double-buffered V_chunk streaming (the hardware prefetch engines'
//!   overlap, §3.1.3);
//! * [`transformer_layer_program`] — one Alg. 1 layer's instruction
//!   stream (projection GEMMs, attention schedule, FFN) used for
//!   instruction-mix statistics and timing studies.
//!
//! Functional correctness of compiled programs is asserted against the
//! golden models in `rust/tests/` (compiler → cycle-sim → same tokens
//! as `sampling::sample_block`).

use crate::config::ModelArch;
use crate::isa::{Instr::*, Program, ProgramBuilder};

/// A GEMM compound sequence: out[m,n] = act[m,k] @ wgt[k,n].
/// act at Vector 0, wgt at Matrix 0, out at Vector `m*k` (after act).
pub fn gemm_program(m: u32, k: u32, n: u32) -> Program {
    let mut b = ProgramBuilder::new();
    b.push(MGemm { dst: m * k, act: 0, wgt: 0, m, k, n, transpose: false });
    b.finish()
}

/// A softmax compound over `len` elements at Vector 0.
pub fn softmax_program(len: u32) -> Program {
    let mut b = ProgramBuilder::new();
    b.push(SSoftmax { v: 0, len });
    b.finish()
}

/// The Table 3 FlashAttention validation sequence (d = 64, H = 2,
/// 6 GEMMs): Q/K/V projections, QKᵀ and AV with HLEN-batched heads,
/// O projection. Shapes follow the paper's per-op breakdown exactly.
pub fn flash_attention_program() -> Program {
    let mut b = ProgramBuilder::new();
    // Q/K/V projections: (1×64)@(64×64), 16 tiles each at BLEN=4/MLEN=64
    b.push(MGemm { dst: 64, act: 0, wgt: 0, m: 1, k: 64, n: 64, transpose: false });
    b.push(MGemm { dst: 128, act: 0, wgt: 4096, m: 1, k: 64, n: 64, transpose: false });
    b.push(MGemm { dst: 192, act: 0, wgt: 8192, m: 1, k: 64, n: 64, transpose: false });
    // QKᵀ: (1×32)@(32×1), heads batched along the MLEN-wide K slice
    b.push(MGemm { dst: 256, act: 64, wgt: 12288, m: 1, k: 32, n: 1, transpose: true });
    // AV: (1×1)@(1×32), 8 tiles
    b.push(MGemm { dst: 260, act: 256, wgt: 12320, m: 1, k: 1, n: 32, transpose: false });
    // O projection
    b.push(MGemm { dst: 292, act: 260, wgt: 12352, m: 1, k: 64, n: 64, transpose: false });
    b.finish()
}

/// Memory layout of a compiled sampling program (element addresses).
#[derive(Clone, Copy, Debug)]
pub struct SamplingLayout {
    pub b: u32,
    pub l: u32,
    pub v: u32,
    pub v_chunk: u32,
    pub mask_id: i32,
    /// HBM element address of the [B*L, V] logit tensor
    pub hbm_logits: u64,
    // Int SRAM regions
    pub x_addr: u32,
    pub x0_addr: u32,
    pub m_idx_addr: u32,
    pub transfer_addr: u32,
    pub scratch_addr: u32,
    // Vector SRAM regions (double-buffered chunk + conf vector)
    pub vbuf0: u32,
    pub vbuf1: u32,
    pub conf_vec: u32,
    // FP SRAM region (per-position confidences, one row at a time)
    pub fp_conf: u32,
}

impl SamplingLayout {
    pub fn new(b: u32, l: u32, v: u32, v_chunk: u32, mask_id: i32) -> Self {
        let bl = b * l;
        let v_chunk = v_chunk.min(v);
        SamplingLayout {
            b,
            l,
            v,
            v_chunk,
            mask_id,
            hbm_logits: 0,
            x_addr: 0,
            x0_addr: bl,
            m_idx_addr: 2 * bl,
            transfer_addr: 3 * bl,
            scratch_addr: 4 * bl,
            vbuf0: 0,
            vbuf1: v_chunk,
            conf_vec: 2 * v_chunk,
            fp_conf: 0,
        }
    }

    /// Required Int SRAM elements (x, x0, m_idx, transfer, scratch).
    pub fn int_elems(&self) -> u32 {
        5 * self.b * self.l
    }

    /// Required Vector SRAM elements (Eq. 4 shape: chunk buffers + conf).
    pub fn vector_elems(&self) -> u32 {
        2 * self.v_chunk + self.l
    }
}

// register conventions for the sampling kernel
const F_MAX: u8 = 0;   // running max (V_RED_MAX_IDX accumulator)
const F_DENOM: u8 = 1; // running Σ exp
const F_NEG1: u8 = 2;  // constant −1
const F_NEGM: u8 = 3;  // −max
const R_IDX: u8 = 0;   // running argmax
const R_K: u8 = 1;     // per-row transfer count

/// Compile Algorithm 2: the full 4-phase intra-block sampling flow.
///
/// Inputs the harness must place before running:
/// * logits in functional HBM at `layout.hbm_logits` ([B*L, V] f32);
/// * current tokens in Int SRAM at `layout.x_addr` ([B, L] i32);
/// * `k[b]` is baked into the instruction stream (S_MOV_I per row).
///
/// Output: updated tokens at `layout.x_addr`; per-position argmax at
/// `x0_addr`; transfer mask at `transfer_addr`.
pub fn sampling_program(layout: &SamplingLayout, k: &[u32]) -> Program {
    assert_eq!(k.len(), layout.b as usize);
    let (_bl, l, v, chunk) = (layout.b * layout.l, layout.l, layout.v,
                             layout.v_chunk);
    let n_chunks = v.div_ceil(chunk);
    let mut p = ProgramBuilder::new();
    p.push(SMovF { dst: F_NEG1, imm: -1.0 });

    for bi in 0..layout.b {
        // ---- Phase 1+2 per position: HBM → Vector → Scalar ------------
        for li in 0..l {
            let pos = bi * l + li;
            let row = layout.hbm_logits + (pos as u64) * v as u64;
            p.push(SMovF { dst: F_MAX, imm: f32::NEG_INFINITY });
            p.push(SMovI { dst: R_IDX, imm: 0 });
            // pass 1: fused max-with-index over streamed chunks
            // (double-buffered: prefetch c+1 overlaps reduce c)
            for c in 0..n_chunks {
                let len = chunk.min(v - c * chunk);
                let buf = if c % 2 == 0 { layout.vbuf0 } else { layout.vbuf1 };
                p.push(HPrefetchV { hbm: row + (c * chunk) as u64, dst: buf, len });
                p.push(VRedMaxIdx { dst_val: F_MAX, dst_idx: R_IDX,
                                    src: buf, len, idx_base: c * chunk });
            }
            // pass 2: Σ exp(z − m) over re-streamed chunks
            p.push(SMulF { dst: F_NEGM, a: F_MAX, b: F_NEG1 });
            p.push(SMovF { dst: F_DENOM, imm: 0.0 });
            for c in 0..n_chunks {
                let len = chunk.min(v - c * chunk);
                let buf = if c % 2 == 0 { layout.vbuf0 } else { layout.vbuf1 };
                p.push(HPrefetchV { hbm: row + (c * chunk) as u64, dst: buf, len });
                p.push(VAddVS { dst: buf, a: buf, s: F_NEGM, len });
                p.push(VExpV { dst: buf, src: buf, len }); // in place
                p.push(VRedSum { dst: F_DENOM, src: buf, len });
            }
            p.push(SRecip { dst: F_MAX, src: F_DENOM }); // conf = 1/Σ
            // Phase 2: scalar write-back into the decoupled domains
            p.push(SStFp { src: F_MAX, addr: layout.fp_conf + li });
            p.push(SStInt { src: R_IDX, addr: layout.x0_addr + pos });
        }
        // ---- Phase 3: Scalar(FP) → Vector → Scalar(Int) ----------------
        let row_i = bi * l;
        p.push(SMapVFp { dst: layout.conf_vec, src: layout.fp_conf, len: l });
        p.push(VEqIs { dst: layout.m_idx_addr + row_i,
                       src: layout.x_addr + row_i,
                       imm: layout.mask_id, len: l });
        p.push(SMovI { dst: R_K, imm: k[bi as usize] as i32 });
        p.push(VTopkMask { dst: layout.transfer_addr + row_i,
                           conf: layout.conf_vec,
                           mask: layout.m_idx_addr + row_i,
                           k: R_K, len: l });
        // ---- Phase 4: integer masked update ----------------------------
        // x0_masked = where(m_idx, x0, x)
        p.push(VSelectInt { dst: layout.scratch_addr + row_i,
                            mask: layout.m_idx_addr + row_i,
                            a: layout.x0_addr + row_i,
                            b: layout.x_addr + row_i, len: l });
        // x = where(transfer, x0_masked, x)
        p.push(VSelectInt { dst: layout.x_addr + row_i,
                            mask: layout.transfer_addr + row_i,
                            a: layout.scratch_addr + row_i,
                            b: layout.x_addr + row_i, len: l });
    }
    p.finish()
}

/// One Alg. 1 transformer layer's instruction stream (timing/statistics
/// view: QKV projections, HLEN-batched attention GEMMs, FFN GEMMs,
/// normalization and activation compound ops, KV quantize + store).
pub fn transformer_layer_program(arch: &ModelArch, m: u32) -> Program {
    let d = arch.d_model as u32;
    let dh = arch.d_head as u32;
    let hq = arch.n_heads as u32;
    let hkv = arch.n_kv_heads as u32;
    let ff = arch.d_ff as u32;
    let kv_len = m; // full bidirectional span within the processed window
    let mut p = ProgramBuilder::new();

    // weight prefetch (sizes in elements; overlapped with compute)
    p.push(HPrefetchM { hbm: 0, dst: 0, len: d * (hq + 2 * hkv) * dh });
    // QKV projections
    p.push(MGemm { dst: 0, act: 0, wgt: 0, m, k: d, n: hq * dh, transpose: false });
    p.push(MGemm { dst: m * hq * dh, act: 0, wgt: d * hq * dh, m, k: d,
                   n: hkv * dh, transpose: false });
    p.push(MGemm { dst: m * (hq + hkv) * dh, act: 0,
                   wgt: d * (hq + hkv) * dh, m, k: d, n: hkv * dh,
                   transpose: false });
    // BAOS + MX quantize newly computed KV, store to HBM (Alg. 1 l.5)
    p.push(VQuantMx { dst: m * hq * dh, src: m * hq * dh,
                      len: 2 * m * hkv * dh, bits: 4 });
    p.push(HStore { src: m * hq * dh, hbm: 1 << 20, len: 2 * m * hkv * dh });
    // bidirectional FlashAttention: per q-tile, QKᵀ + softmax + AV
    for h in 0..hq.div_ceil(crate::config::HwConfig::dart_default().hlen) {
        let base = h * m * kv_len;
        p.push(MGemm { dst: base, act: 0, wgt: 0, m, k: dh, n: kv_len,
                       transpose: true });
        p.push(SSoftmax { v: base, len: kv_len });
        p.push(MGemm { dst: base, act: base, wgt: 0, m, k: kv_len, n: dh,
                       transpose: false });
    }
    // O projection + residual + norm
    p.push(MGemm { dst: 0, act: 0, wgt: 0, m, k: hq * dh, n: d, transpose: false });
    p.push(VAddVV { dst: 0, a: 0, b: 0, len: m * d });
    p.push(SLayerNorm { v: 0, len: d });
    // FFN (SwiGLU): gate, up, silu·mul, down
    p.push(MGemm { dst: 0, act: 0, wgt: 0, m, k: d, n: ff, transpose: false });
    p.push(MGemm { dst: m * ff, act: 0, wgt: d * ff, m, k: d, n: ff,
                   transpose: false });
    p.push(SSilu { v: 0, len: m * ff });
    p.push(VMulVV { dst: 0, a: 0, b: m * ff, len: m * ff });
    p.push(MGemm { dst: 0, act: 0, wgt: 0, m, k: ff, n: d, transpose: false });
    p.push(VAddVV { dst: 0, a: 0, b: 0, len: m * d });
    p.push(SLayerNorm { v: 0, len: d });
    p.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelArch;

    #[test]
    fn gemm_program_shape() {
        let p = gemm_program(1, 64, 64);
        assert_eq!(p.instrs.len(), 2); // gemm + halt
        assert!(p.validate().is_ok());
    }

    #[test]
    fn flash_attention_has_six_gemms() {
        let p = flash_attention_program();
        let gemms = p.instrs.iter()
            .filter(|i| i.mnemonic() == "M_GEMM").count();
        assert_eq!(gemms, 6);
    }

    #[test]
    fn sampling_program_structure() {
        let layout = SamplingLayout::new(2, 8, 256, 64, 0);
        let p = sampling_program(&layout, &[2, 3]);
        assert!(p.validate().is_ok());
        let h = p.histogram();
        let count = |m: &str| h.iter().find(|(n, _)| *n == m)
            .map(|(_, c)| *c).unwrap_or(0);
        // 2 passes x 4 chunks x 16 positions prefetches
        assert_eq!(count("H_PREFETCH_V"), 2 * 4 * 16);
        assert_eq!(count("V_RED_MAX_IDX"), 4 * 16);
        assert_eq!(count("V_TOPK_MASK"), 2);
        assert_eq!(count("V_SELECT_INT"), 4);
        assert_eq!(count("S_ST_FP"), 16);
        assert_eq!(count("S_ST_INT"), 16);
    }

    #[test]
    fn sampling_layout_domains_disjoint() {
        let lo = SamplingLayout::new(4, 16, 1024, 128, 0);
        assert!(lo.x0_addr >= lo.x_addr + lo.b * lo.l);
        assert!(lo.m_idx_addr >= lo.x0_addr + lo.b * lo.l);
        assert!(lo.transfer_addr >= lo.m_idx_addr + lo.b * lo.l);
        assert!(lo.scratch_addr >= lo.transfer_addr + lo.b * lo.l);
        assert!(lo.vbuf1 >= lo.vbuf0 + lo.v_chunk);
        assert!(lo.conf_vec >= lo.vbuf1 + lo.v_chunk);
    }

    #[test]
    fn transformer_layer_instruction_mix() {
        let p = transformer_layer_program(&ModelArch::tiny(), 16);
        assert!(p.validate().is_ok());
        let h = p.histogram();
        let gemms = h.iter().find(|(n, _)| *n == "M_GEMM").unwrap().1;
        assert!(gemms >= 7); // 3 proj + attention pairs + o + 3 ffn
        assert!(h.iter().any(|(n, _)| *n == "V_QUANT_MX"));
        assert!(h.iter().any(|(n, _)| *n == "H_STORE"));
    }
}
