//! # DART — an NPU stack for Diffusion-LLM inference
//!
//! Rust reproduction of *"Beyond GEMM-Centric NPUs: Enabling Efficient
//! Diffusion LLM Sampling"* (DART): the first configurable NPU platform
//! for dLLM inference. This crate is Layer 3 of the three-layer stack
//! described in `docs/ARCHITECTURE.md` (layer diagram + data-flow
//! walkthrough):
//!
//! * [`isa`] / [`compiler`] — the dLLM-oriented ISA and the model→ISA
//!   compiler (paper §3.1.3, Table 1, Algorithms 1–2);
//! * [`sim`] — the tri-path simulation framework: analytical roofline,
//!   transaction-level cycle-accurate, and RTL-reference pipeline models
//!   (paper §4.1–§4.2, §5);
//! * [`hbm`] / [`mem`] — the HBM2e DRAM model and the decoupled
//!   three-domain on-chip SRAM hierarchy (paper §3.2.2, §5.1);
//! * [`sampling`] — the Vector-Scalar sampling engine golden model:
//!   Stable-Max decomposition, streaming top-k, masked integer update
//!   (paper §3.2);
//! * [`schedule`] — adaptive denoising schedules: the
//!   [`schedule::SchedulePolicy`] trait (fixed / confidence-threshold /
//!   SlowFast stepping), deterministic [`schedule::StepTrace`] records,
//!   and the synthetic confidence process that prices expected realized
//!   steps for every cost model above (`schedule_sweep` in the benches,
//!   `--schedule` on the serving CLIs);
//! * [`cache`] — cross-step feature caching as a serving dimension: the
//!   [`cache::CachePolicySpec`] policies (off / interval / adaptive
//!   refresh of prompt and response features), the deterministic
//!   [`cache::CacheStats`] accounting, and the synthetic feature-drift
//!   process (S10) that prices expected refresh/reuse mixes for every
//!   cost model above (`cache_sweep` in the benches, `--cache` on the
//!   serving CLIs, `rust/tests/cache_equivalence.rs` the differential
//!   gate);
//! * [`quant`] / [`kvcache`] — bit-exact MX formats, BAOS online
//!   smoothing, and the blocked-diffusion KV cache manager
//!   (paper §2.2, §3.1.1, §4.4);
//! * [`runtime`] / [`coordinator`] — the PJRT artifact runtime and the
//!   serving coordinator that executes real blocked-diffusion generation
//!   end-to-end with python never on the request path;
//! * [`cluster`] — the scale-out layer above the coordinator: the
//!   paper's Fig. 2 host side replicated into a multi-NPU fleet, with a
//!   data-parallel request router, SLO-aware (TTFT/TPOT) admission
//!   scheduling, trace-driven load generation, and cluster-wide
//!   goodput/utilization/padding-waste metrics (`serve-cluster` in the
//!   CLI, `fleet_scaling` in the benches);
//! * [`calib`] — device calibration: measured batch-variant latency
//!   curves (latency vs batch size × seq-len bucket, p50/p95 spread)
//!   profiled through the tri-path simulator, persisted in a replayable
//!   text format, and threaded through the batcher's cost-based flush
//!   policy and the scheduler's percentile TTFT admission predictor
//!   (`calibrate` in the CLI, `calib_policies` in the benches);
//! * [`replay`] — closed-loop recalibration above calib + cluster:
//!   measured serving observations (per-batch latency, variant,
//!   seq-len cell, realized steps) drain into a replayable
//!   `ObservationLog` and fold back into the curve tables via a
//!   fixed-point-exact percentile blend, so admission and batching
//!   re-price from what serving actually measured
//!   (`serve-cluster --recalibrate` in the CLI, `recalib_loop` in the
//!   benches, `rust/tests/recalib_convergence.rs` the gate);
//! * [`memmodel`] — per-device memory residency as a serving
//!   constraint: the [`memmodel::MemoryPlan`] accounting of weights,
//!   fp16/int logits buffers (lanes × block × vocab — the paper's
//!   dominant traffic, now priced in bytes held as well as bytes
//!   moved), KV and feature-cache residency, and per-lane block state
//!   (docs/ARCHITECTURE.md S11), consulted by the batcher (variant
//!   downshift under pressure) and the fleet scheduler (memory sheds
//!   instead of OOM) whenever a device declares a finite capacity
//!   (`--mem-cap` on the serving CLIs, `mem_pressure_sweep` in the
//!   benches, `rust/tests/mem_pressure.rs` the differential gate);
//! * [`study`] — the fleet study harness above cluster + calib:
//!   parameterized experiment grids (fleet shape × router policy ×
//!   admission mode under diurnal traces) whose output artifact is a
//!   committed, byte-reproducible Markdown report (`fleet-study` in the
//!   CLI, `fleet_study` in the benches, `docs/STUDY_fleet.md` the
//!   generated document);
//! * [`obs`] — deterministic observability threaded through all of the
//!   above: hierarchical spans carrying virtual time (sim seconds /
//!   scheduler clock) plus named counters (HBM/SRAM bytes, logit-buffer
//!   traffic, events dispatched, sheds by reason), zero-overhead when
//!   disabled, exported as Chrome-trace JSON (`--trace` on the serving
//!   CLIs) and as the byte-stable committed profile (`profile` in the
//!   CLI, `docs/PROFILE.md` the generated document);
//! * [`window`] — suffix windowing as a serving dimension, opening
//!   long-context serving: the [`window::WindowPolicySpec`] policies
//!   (full / sliding / distance-decay dropout over distant suffix
//!   tokens), the deterministic [`window::WindowStats`] accounting,
//!   and the synthetic suffix-retention process (S12) whose
//!   closed-form expected active-suffix length every cost model above
//!   bills instead of the full remaining suffix — composing with the
//!   memory model so windowing relieves residency sheds, and opening
//!   a long-form (8–64K token) request class with per-class SLOs and
//!   schedules in the fleet (`window_sweep` in the benches,
//!   `--window` on the serving CLIs,
//!   `rust/tests/window_equivalence.rs` the differential gate);
//! * [`gpu`] — analytical A6000/H100 baselines for Table 6 / Fig. 9.
//!
//! Substrates ([`cli`], [`stats`], [`report`], [`util`]) are built from
//! scratch because the offline crate registry lacks clap/criterion/serde
//! (docs/ARCHITECTURE.md, substitution S7).

pub mod cache;
pub mod calib;
pub mod cli;
pub mod cluster;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod gpu;
pub mod hbm;
pub mod isa;
pub mod kvcache;
pub mod mem;
pub mod memmodel;
pub mod obs;
pub mod quant;
pub mod replay;
pub mod report;
pub mod runtime;
pub mod sampling;
pub mod schedule;
pub mod sim;
pub mod stats;
pub mod study;
pub mod util;
pub mod window;
