//! Quickstart: load the AOT artifacts, run one blocked-diffusion
//! generation end-to-end through the Rust stack, print the result.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! What happens: the PJRT runtime compiles the HLO-text executables the
//! python layer lowered at build time; the generation engine runs the
//! Fast-dLLM dual-cache schedule (warm step + in-place refinements); the
//! Rust sampling engine (Stable-Max + streaming top-k) commits tokens.

use dart::config::CacheMode;
use dart::coordinator::{EngineConfig, GenerationEngine};
use dart::runtime::{artifacts_dir, Executor};

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir()
        .expect("artifacts not built — run `make artifacts` first");
    let ex = Executor::load(&dir)?;
    let g = ex.manifest.geometry;
    println!("model: {} params, vocab {}, L_tot {}, {} blocks x {} steps",
             ex.weights.total_params(), g.vocab, g.total_len, g.n_blocks,
             g.steps_per_block);

    let mut eng = GenerationEngine::new(ex, EngineConfig {
        cache: CacheMode::Dual,
        ..EngineConfig::default()
    });

    // a prompt from the trained task family ("step": s_i = a + i*stride)
    let (a, stride) = (9i32, 3i32);
    let prompt: Vec<i32> = (0..g.prompt_len as i32)
        .map(|i| (a + i * stride) % 48 + 4).collect();
    println!("prompt:      {prompt:?}");

    let r = eng.generate(&[prompt.clone()])?;
    let out = &r.tokens[0];
    println!("continuation {:?}", &out[g.prompt_len..]);

    // the continuation of the deterministic task, for reference
    let expect: Vec<i32> = (g.prompt_len as i32..g.total_len as i32)
        .map(|i| (a + i * stride) % 48 + 4).collect();
    let correct = out[g.prompt_len..].iter().zip(&expect)
        .filter(|(x, y)| x == y).count();
    println!("task accuracy: {}/{} tokens", correct, expect.len());
    println!("timing: model {:.1} ms, sampling {:.1} ms ({:.1}%), {} steps",
             r.model_s * 1e3, r.sampling_s * 1e3,
             r.sampling_frac() * 100.0, r.steps);
    Ok(())
}
