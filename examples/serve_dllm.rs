//! End-to-end serving driver (deliverable (e) / EXPERIMENTS.md §E2E):
//! starts the DART coordinator, submits a batched stream of generation
//! requests against the real PJRT-compiled dLLM, and reports latency
//! percentiles, throughput, and the model/sampling breakdown — the
//! serving-paper analogue of "load a small real model and serve batched
//! requests".
//!
//!     cargo run --release --example serve_dllm -- [n_requests] [cache]

use std::time::Instant;

use dart::config::CacheMode;
use dart::coordinator::{Coordinator, EngineConfig};
use dart::kvcache::KvQuantPolicy;
use dart::quant::BaosVariant;
use dart::runtime::artifacts_dir;
use dart::util::SplitMix64;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|v| v.parse().ok()).unwrap_or(32);
    let cache = args.get(1).and_then(|v| CacheMode::parse(v))
        .unwrap_or(CacheMode::Dual);
    let dir = artifacts_dir()
        .expect("artifacts not built — run `make artifacts` first");

    println!("== DART serving driver: {n} requests, {} cache, \
              BAOS-MXINT4 KV ==", cache.name());
    let t0 = Instant::now();
    let coord = Coordinator::start(&dir, EngineConfig {
        cache,
        kv_policy: KvQuantPolicy::mxint4_baos(BaosVariant::Mean, 1.0),
        ..EngineConfig::default()
    }, None)?;
    println!("coordinator up in {:.2}s (artifacts compiled)",
             t0.elapsed().as_secs_f64());

    // submit a bursty open-loop stream of prompts from the trained tasks
    let mut rng = SplitMix64::new(2026);
    let prompt_len = 16;
    let submit_t = Instant::now();
    let handles: Vec<_> = (0..n).map(|i| {
        let a = rng.range(0, 40) as i32;
        let stride = rng.range(1, 5) as i32;
        let prompt: Vec<i32> = (0..prompt_len)
            .map(|j| (a + j * stride) % 48 + 4).collect();
        // light jitter between bursts
        if i % 8 == 7 {
            std::thread::sleep(std::time::Duration::from_millis(3));
        }
        coord.submit(prompt)
    }).collect();

    let mut ok = 0usize;
    for h in &handles {
        if h.recv().is_ok() {
            ok += 1;
        }
    }
    let wall = submit_t.elapsed().as_secs_f64();
    let metrics = coord.shutdown();

    println!("\n== results ==");
    println!("{}", metrics.report());
    println!("completed {ok}/{n} in {wall:.2}s wall");
    println!("\nrecord these rows in EXPERIMENTS.md §E2E");
    Ok(())
}
