//! KV-quantization demo on *real* model activations: pull the KV cache
//! out of the PJRT warm step and compare naive MXINT4, QuaRot-style
//! rotation (python-side baseline), and BAOS smoothing — per-layer error
//! statistics plus the end-token-level effect on generation.
//!
//!     cargo run --release --example kv_quant_demo

use dart::config::CacheMode;
use dart::coordinator::{EngineConfig, GenerationEngine};
use dart::kvcache::KvQuantPolicy;
use dart::quant::{fake_quant, BaosFactors, BaosVariant, MxFormat};
use dart::report::{self, Table};
use dart::runtime::{artifacts_dir, Executor, Tensor};

fn l2(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>().sqrt()
}

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir()
        .expect("artifacts not built — run `make artifacts` first");
    let mut ex = Executor::load(&dir)?;
    let g = ex.manifest.geometry;

    // 1. real KV from a warm step over a task prompt
    let mut tokens = vec![g.mask_id; g.total_len];
    for (i, t) in tokens.iter_mut().enumerate().take(g.prompt_len) {
        *t = ((i as i32 * 5) % 48) + 4;
    }
    let out = ex.run("full_b1", &[Tensor::i32(vec![1, g.total_len], tokens)])?;
    let k = out[1].as_f32();

    // per-channel magnitude profile (the §4.4 outlier statistic)
    let d = g.d_head;
    let mut chan_max = vec![0f32; d];
    for (i, &v) in k.iter().enumerate() {
        let c = i % d;
        chan_max[c] = chan_max[c].max(v.abs());
    }
    let mean: f32 = chan_max.iter().sum::<f32>() / d as f32;
    let peak = chan_max.iter().cloned().fold(0f32, f32::max);
    println!("K-cache channel profile: mean |max| {mean:.3}, \
              peak channel {:.3} ({:.1}x mean)", peak, peak / mean);

    // 2. quantization error comparison on the K tensor
    let groups = g.n_layers * g.n_kv_heads; // B=1
    let seq = g.total_len;
    let mut t = Table::new("K-cache MXINT4 quantization error (L2)",
                           &["scheme", "error", "vs naive"]);
    let naive = l2(k, &fake_quant(k, MxFormat::MxInt4));
    t.row(&["naive KV4".into(), report::f3(naive), "x1.00".into()]);
    for (name, variant, alpha) in [
        ("BAOS mean a=1.0", BaosVariant::Mean, 1.0f32),
        ("BAOS mean a=0.9", BaosVariant::Mean, 0.9),
        ("BAOS mean a=0.6", BaosVariant::Mean, 0.6),
        ("BAOS minmax a=1.0", BaosVariant::MinMax, 1.0),
        ("BAOS minmax a=0.6", BaosVariant::MinMax, 0.6),
    ] {
        let f = BaosFactors::calibrate(k, groups, seq, d, variant, alpha);
        let q = f.fake_quant(k, MxFormat::MxInt4);
        let e = l2(k, &q);
        t.row(&[name.into(), report::f3(e),
                format!("x{:.2}", e / naive)]);
    }
    t.print();

    // 3. token-level effect on full generation
    let prompt: Vec<i32> = (0..g.prompt_len as i32)
        .map(|i| (7 + i * 2) % 48 + 4).collect();
    let mut rows = Table::new("generation agreement vs fp32 KV cache",
                              &["policy", "agree", "cache bytes"]);
    let fp = {
        let ex = Executor::load(&dir)?;
        let mut eng = GenerationEngine::new(ex, EngineConfig {
            cache: CacheMode::Dual, ..EngineConfig::default()
        });
        eng.generate(&[prompt.clone()])?
    };
    for (name, policy) in [
        ("fp32", KvQuantPolicy::fp32()),
        ("naive mxint4", KvQuantPolicy::mxint4_naive()),
        ("baos mxint4", KvQuantPolicy::mxint4_baos(BaosVariant::Mean, 1.0)),
    ] {
        let ex = Executor::load(&dir)?;
        let mut eng = GenerationEngine::new(ex, EngineConfig {
            cache: CacheMode::Dual,
            kv_policy: policy,
            ..EngineConfig::default()
        });
        let r = eng.generate(&[prompt.clone()])?;
        let agree = r.tokens[0].iter().zip(&fp.tokens[0])
            .filter(|(a, b)| a == b).count() as f64
            / fp.tokens[0].len() as f64;
        rows.row(&[name.into(), report::pct(agree),
                   r.kv_packed_bytes.to_string()]);
    }
    rows.print();
    Ok(())
}
