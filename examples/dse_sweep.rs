//! Design-space exploration (Fig. 9): sweep (VLEN, MLEN, BLEN) across
//! the three inference paradigms for dense + MoE models and print the
//! TPS-vs-tok/J frontier against the A6000/H100 baselines.
//!
//!     cargo run --release --example dse_sweep [-- --csv]

use dart::config::{CacheMode, HwConfig, ModelArch, Workload};
use dart::gpu::GpuSpec;
use dart::report::{self, Table};
use dart::sampling::SamplePrecision;
use dart::sim::analytical::{AnalyticalSim, PrecisionConfig};

fn main() {
    let csv = std::env::args().any(|a| a == "--csv");
    for model in [ModelArch::llada_8b(), ModelArch::llada_moe_7b()] {
        let mut t = Table::new(
            &format!("Fig. 9 sweep — {}", model.name),
            &["device", "cache", "VLEN", "MLEN", "BLEN", "TPS", "tok/J"]);
        for cache in CacheMode::ALL {
            let w = Workload::paper_reference(model.clone(), cache);
            // GPU baselines (one point each per paradigm)
            for gpu in [GpuSpec::a6000(), GpuSpec::h100()] {
                let r = gpu.run(&w, SamplePrecision::Bf16);
                t.row(&[gpu.name.clone(), cache.name().into(),
                        "-".into(), "-".into(), "-".into(),
                        report::f1(r.tps), report::f3(r.tok_per_j)]);
            }
            for vlen in [256u32, 512, 1024, 2048] {
                for mlen in [256u32, 512, 1024] {
                    for blen in [4u32, 16, 64] {
                        if mlen < blen {
                            continue;
                        }
                        let hw = HwConfig::dart_default()
                            .with_dims(blen, mlen, vlen);
                        let sim = AnalyticalSim::new(
                            hw, PrecisionConfig::dart_full_quant());
                        let r = sim.run(&w);
                        t.row(&["DART".into(), cache.name().into(),
                                vlen.to_string(), mlen.to_string(),
                                blen.to_string(), report::f1(r.tps),
                                report::f3(r.tok_per_j)]);
                    }
                }
            }
        }
        if csv {
            println!("{}", t.to_csv());
        } else {
            t.print();
        }
        // frontier summary: best DART point per paradigm vs GPUs
        for cache in CacheMode::ALL {
            let w = Workload::paper_reference(model.clone(), cache);
            let a = GpuSpec::a6000().run(&w, SamplePrecision::Bf16);
            let best = [256u32, 512, 1024, 2048].iter().flat_map(|&vlen| {
                [256u32, 512, 1024].iter().flat_map(move |&mlen| {
                    [4u32, 16, 64].iter().filter(move |&&b| b <= mlen)
                        .map(move |&blen| (vlen, mlen, blen))
                })
            }).map(|(vlen, mlen, blen)| {
                let hw = HwConfig::dart_default().with_dims(blen, mlen, vlen);
                let r = AnalyticalSim::new(
                    hw, PrecisionConfig::dart_full_quant()).run(&w);
                (r.tps, r.tok_per_j, vlen, mlen, blen)
            }).max_by(|x, y| x.0.partial_cmp(&y.0).unwrap()).unwrap();
            println!(
                "{} {}: best DART (VLEN={} MLEN={} BLEN={}) = {} TPS \
                 ({} vs A6000), {} tok/J ({} vs A6000)",
                model.name, cache.name(), best.2, best.3, best.4,
                report::f1(best.0), report::speedup(best.0 / a.tps),
                report::f3(best.1), report::speedup(best.1 / a.tok_per_j));
        }
        println!();
    }
}
