//! Cluster walkthrough: build a 4-NPU DART fleet, generate a Poisson
//! trace at 60% of fleet capacity, serve it with SLO-aware scheduling,
//! then stress the same fleet with a bursty trace and compare routers —
//! all on the analytical device model (no AOT artifacts needed).
//!
//!     cargo run --release --example cluster_sim

use dart::cluster::{chat_offered_rps, fleet_capacity_tps, generate_trace,
                    trace_from_text, trace_to_text, Arrival,
                    ClusterTopology, FleetSim, RoutePolicy, SloConfig,
                    TraceSpec};
use dart::config::{CacheMode, HwConfig, ModelArch};

fn main() {
    // 1. describe the fleet: 4 identical paper-operating-point devices
    //    serving LLaDA-8B under the Fast-dLLM dual cache
    let topo = ClusterTopology::homogeneous(
        4, HwConfig::dart_default(), ModelArch::llada_8b(), CacheMode::Dual);
    let capacity = fleet_capacity_tps(&topo);
    println!("fleet: {} devices, ~{capacity:.0} generated tok/s capacity",
             topo.n_devices());

    // 2. a Poisson chat trace at 60% of capacity, deterministic seed
    let rps = chat_offered_rps(capacity, 0.6);
    let spec = TraceSpec::chat(256, Arrival::Poisson { rps }, 7);
    let trace = generate_trace(&spec);
    println!("trace: {} requests at {rps:.2} req/s (60% load)\n",
             trace.len());

    // traces round-trip through the replay format, so a run can be
    // captured once and re-served identically across experiments
    let replayed = trace_from_text(&trace_to_text(&trace)).unwrap();
    assert_eq!(replayed.len(), trace.len());

    // 3. serve it: SLO deadlines derived from the unloaded service curve
    let slo = SloConfig::auto(&topo);
    println!("auto SLO: TTFT <= {:.0} ms, TPOT <= {:.2} ms/tok",
             slo.ttft_s * 1e3, slo.tpot_s * 1e3);
    let mut sim = FleetSim::new(topo.clone(), RoutePolicy::LeastOutstanding,
                                slo);
    let m = sim.run(&trace);
    println!("\n--- steady 60% load, least-outstanding router ---");
    println!("{}", m.report(Some((slo.ttft_s, slo.tpot_s))));

    // 4. the same average rate but bursty (4x spikes, 25% duty): goodput
    //    drops and sheds appear — the scheduler degrades by rejecting
    //    early instead of blowing every deadline
    let bursty = generate_trace(&TraceSpec::chat(
        256,
        Arrival::Bursty { rps, burst_mult: 4.0, cycle_s: 30.0, duty: 0.25 },
        7));
    for policy in [RoutePolicy::RoundRobin, RoutePolicy::LeastOutstanding,
                   RoutePolicy::VariantAware] {
        let mut sim = FleetSim::new(topo.clone(), policy, slo);
        let b = sim.run(&bursty);
        println!(
            "bursty / {:<17} goodput {:>7.1} tok/s  shed {:>3}  \
             p99 TTFT {:>8}  waste {}",
            policy.name(), b.goodput_tps(), b.shed(),
            dart::stats::fmt_time(b.ttft.summary().map(|s| s.p99)
                                  .unwrap_or(0.0)),
            dart::report::pct(b.padding_waste_frac()));
    }
}
