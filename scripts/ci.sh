#!/usr/bin/env bash
# CI gate for the DART repo.
#
#   scripts/ci.sh           tier-1 gate: release build + tests + fmt check
#   scripts/ci.sh --smoke   tier-1 gate + fast fleet-scaling smoke run
#
# The tier-1 gate (ROADMAP.md) must stay green: `cargo build --release &&
# cargo test -q`. rustfmt is checked when the component is installed so
# minimal toolchains still pass the gate.

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

if cargo fmt --version >/dev/null 2>&1; then
    echo "== style: cargo fmt --check =="
    cargo fmt --check
else
    echo "== style: rustfmt not installed, skipping fmt check =="
fi

if [[ "${1:-}" == "--smoke" ]]; then
    echo "== smoke: fleet_scaling bench (reduced trace) =="
    cargo bench --bench fleet_scaling -- --smoke
    echo "== smoke: serve-cluster 2 devices x 32 requests =="
    cargo run --release -- serve-cluster --devices 2 --requests 32
fi

echo "ci: OK"
