#!/usr/bin/env bash
# CI gate for the DART repo.
#
#   scripts/ci.sh           tier-1 gate: release build + tests + fmt/lint
#                           + test-count regression guard + docs gate
#   scripts/ci.sh --smoke   tier-1 gate + fast fleet/calib smoke runs
#                           + committed-doc drift checks (fleet-study,
#                           profile) + observability artifact validation
#
# The tier-1 gate (ROADMAP.md) must stay green: `cargo build --release &&
# cargo test -q`. rustfmt/clippy are checked when the components are
# installed so minimal toolchains still pass the gate.
#
# The test-count guard ratchets: the total passing-test count is compared
# against scripts/test_baseline.txt and must never drop; when it grows,
# the baseline file is advanced in place (commit it with the change that
# added the tests).

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
test_log=$(mktemp)
cargo test -q 2>&1 | tee "$test_log"

# sum "N passed" across every test binary in the run
passed=$(grep -Eo '[0-9]+ passed' "$test_log" | awk '{s+=$1} END {print s+0}')
rm -f "$test_log"
baseline_file="scripts/test_baseline.txt"
recorded=0
if [[ -f "$baseline_file" ]]; then
    recorded=$(grep -Eo '^[0-9]+' "$baseline_file" | head -1 || true)
    recorded=${recorded:-0}
fi
echo "== tier-1: test-count guard: $passed passing (baseline $recorded) =="
if (( passed < recorded )); then
    echo "FAIL: passing-test count dropped from $recorded to $passed"
    exit 1
fi
if (( passed > recorded )); then
    {
        echo "$passed"
        echo "# tier-1 passing-test count baseline (auto-ratcheted by"
        echo "# scripts/ci.sh; must never drop). Commit this file when"
        echo "# it advances, or the ratchet has no teeth on fresh checkouts."
    } > "$baseline_file"
    echo "baseline advanced $recorded -> $passed: COMMIT $baseline_file"
    if [[ "${CI_RATCHET_STRICT:-0}" == "1" ]]; then
        echo "FAIL (CI_RATCHET_STRICT): baseline file is stale; commit the"
        echo "advanced $baseline_file with this change"
        exit 1
    fi
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== style: cargo fmt --check =="
    cargo fmt --check
else
    echo "== style: rustfmt not installed, skipping fmt check =="
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== lint: cargo clippy --all-targets -- -D warnings =="
    cargo clippy --all-targets -- -D warnings
else
    echo "== lint: clippy not installed, skipping lint check =="
fi

# docs gate: rustdoc must build clean (broken intra-doc links and bad
# examples are errors, not noise) — doctests themselves already ran
# under `cargo test -q` above
echo "== docs: cargo doc --no-deps (warnings as errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

if [[ "${1:-}" == "--smoke" ]]; then
    echo "== smoke: fleet_scaling bench (reduced trace) =="
    cargo bench --bench fleet_scaling -- --smoke
    echo "== smoke: calib_policies bench (reduced trace) =="
    cargo bench --bench calib_policies -- --smoke
    echo "== smoke: fleet_study bench (reduced grid) =="
    cargo bench --bench fleet_study -- --smoke
    echo "== smoke: schedule_sweep bench (reduced geometry) =="
    cargo bench --bench schedule_sweep -- --smoke
    echo "== smoke: Fixed-schedule equivalence (seed-engine differential) =="
    cargo test -q --test schedule_equivalence
    echo "== smoke: cache-equivalence differential gate (Off == pre-cache, bit-exact) =="
    cargo test -q --test cache_equivalence
    echo "== smoke: cache_sweep bench (reduced trace) =="
    cargo bench --bench cache_sweep -- --smoke
    echo "== smoke: recalibration fixed-point + convergence gate =="
    cargo test -q --test recalib_convergence
    echo "== smoke: recalib_loop bench (reduced trace) =="
    cargo bench --bench recalib_loop -- --smoke
    echo "== smoke: serve-cluster 2 devices x 32 requests, calibrated =="
    cargo run --release -- serve-cluster --devices 2 --requests 32 --calibrated
    echo "== smoke: serve-cluster slowfast schedule, calibrated =="
    cargo run --release -- serve-cluster --devices 2 --requests 32 \
        --calibrated --schedule slowfast
    echo "== smoke: serve-cluster adaptive feature cache, calibrated =="
    cargo run --release -- serve-cluster --devices 2 --requests 32 \
        --calibrated --cache dual,adaptive
    echo "== smoke: serve-cluster replay loop (warm-up -> recalibrate -> re-serve) =="
    cargo run --release -- serve-cluster --devices 2 --requests 32 \
        --recalibrate
    echo "== smoke: memory-pressure accounting + differential gate (off == infinite capacity, bit-exact) =="
    cargo test -q --test mem_pressure
    echo "== smoke: mem_pressure_sweep bench (reduced trace) =="
    cargo bench --bench mem_pressure_sweep -- --smoke
    echo "== smoke: serve-cluster under a 18GiB per-device memory cap, calibrated =="
    cargo run --release -- serve-cluster --devices 2 --requests 32 \
        --calibrated --mem-cap 18GiB
    echo "== smoke: suffix-window equivalence differential gate (full == pre-window, bit-exact) =="
    cargo test -q --test window_equivalence
    echo "== smoke: window_sweep bench (reduced trace) =="
    cargo bench --bench window_sweep -- --smoke
    echo "== smoke: serve-cluster windowed long-form blend, calibrated =="
    cargo run --release -- serve-cluster --devices 2 --requests 32 \
        --calibrated --window decay:2048:0.95 --long-share 0.5
    echo "== smoke: observability goldens (zero-alloc recorder + byte-stable trace summary) =="
    cargo test -q --test trace_golden
    echo "== smoke: --trace export + Chrome-trace JSON validation =="
    trace_tmp=$(mktemp)
    cargo run --release -- serve-cluster --devices 2 --requests 32 \
        --trace "$trace_tmp"
    cargo run --release -- profile --check-trace "$trace_tmp"
    rm -f "$trace_tmp"
    echo "== smoke: bench JSON schema check (BENCH_6.json, BENCH_10.json) =="
    cargo run --release -- profile --check-bench BENCH_6.json
    cargo run --release -- profile --check-bench BENCH_10.json

    # Committed-artifact drift checks. Artifacts authored without a
    # toolchain carry a "Provisional" banner and would legitimately
    # drift from a real regen, so they are skipped with ONE consolidated
    # warning instead of failing one by one; regenerating an artifact on
    # real hardware (dropping its banner) re-arms its gate automatically.
    provisional=()
    for f in docs/STUDY_fleet.md docs/PROFILE.md BENCH_6.json BENCH_10.json; do
        if [[ -f "$f" ]] && grep -qi "provisional" "$f"; then
            provisional+=("$f")
        fi
    done
    if (( ${#provisional[@]} > 0 )); then
        echo "== WARNING: provisional artifacts (authored without a toolchain):"
        printf '==   %s\n' "${provisional[@]}"
        echo "== drift + perf-regression gates skipped for these; regenerate"
        echo "== them on real hardware and drop the banners to re-arm =="
    fi
    skip() {
        local f
        for f in "${provisional[@]}"; do
            [[ "$f" == "$1" ]] && return 0
        done
        return 1
    }
    if skip docs/STUDY_fleet.md; then
        echo "== docs: fleet-study regen check SKIPPED (provisional) =="
    else
        echo "== docs: fleet-study regen check (committed study must not drift) =="
        cargo run --release -- fleet-study --smoke
    fi
    if skip docs/PROFILE.md; then
        echo "== docs: profile regen check SKIPPED (provisional) =="
    else
        echo "== docs: profile regen check (committed profile must not drift) =="
        cargo run --release -- profile --smoke
    fi

    # Fleet events/s regression gate: rerun the hot-path bench and fail
    # if the indexed fleet scheduler lost >20% events/s against the
    # committed BENCH_10.json row. Armed the first time this runs with a
    # toolchain on real numbers (the provisional banner disarms it).
    if skip BENCH_10.json; then
        echo "== perf: fleet events/s gate SKIPPED (BENCH_10.json provisional) =="
    else
        echo "== perf: fleet events/s gate (>=80% of committed BENCH_10.json) =="
        bench_tmp=$(mktemp)
        cargo bench --bench perf_hotpaths -- --json "$bench_tmp"
        fleet_row="fleet: indexed scheduler 8dev x 512req"
        eps() {
            tr ',' '\n' < "$1" \
                | grep -A2 -F "\"name\":\"$fleet_row\"" \
                | grep -Eo '"events_per_sec":[0-9.eE+-]+' \
                | head -1 | cut -d: -f2
        }
        measured=$(eps "$bench_tmp"); committed=$(eps BENCH_10.json)
        rm -f "$bench_tmp"
        if [[ -z "$measured" || -z "$committed" ]]; then
            echo "FAIL: could not extract \"$fleet_row\" events/s"
            exit 1
        fi
        awk -v m="$measured" -v c="$committed" 'BEGIN {
            if (m < 0.8 * c) {
                printf "FAIL: fleet events/s regressed: %.0f < 80%% of committed %.0f\n", m, c
                exit 1
            }
            printf "perf gate OK: %.0f events/s vs committed %.0f\n", m, c
        }'
    fi
fi

echo "ci: OK"
