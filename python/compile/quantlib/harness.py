"""Table 5 accuracy machinery: quantization quality over blocked decoding.

Substitution S5 (DESIGN.md): LLaDA-8B + GSM8K/HumanEval are replaced by
the tiny trained denoiser + deterministic synthetic tasks; the metric is
exact-match / token accuracy of the generated continuation, and the
experiment compares the *same tracks* as the paper's Table 5:

  sampling track : FP32-reference vs BF16 vs MXFP8 logits
  KV track       : KV4 (naive MXINT4), QuaRot rotation, BAOS
                   (mean ᾱ / minmax α̂ × α ∈ {1.0, 0.9, 0.6})
  weight track   : W4 (RTN MXINT4), GPTQ, GPTQ + x-clip / y-clip
  full stack     : best KV + best W4 + BF16 sampling

over both prefix-cache and dual-cache decoding.
"""

import numpy as np
import jax
import jax.numpy as jnp

from ..configs import ModelConfig, GenConfig
from .. import model as M
from ..kernels.ref import attention_ref, rmsnorm_ref
from . import mx, baos, rotation, gptq


# ---------------------------------------------------------------------------
# Calibration capture: inputs to every quantized linear layer
# ---------------------------------------------------------------------------

def capture_calib(cfg: ModelConfig, params, tokens):
    """Run forward_full capturing the input activations of each linear.

    Returns {weight_name: {layer_index: X [M, K]}} for the per-layer
    stacked weights. Mirrors model.forward_full exactly (asserted in
    tests by comparing final logits).
    """
    p = params
    caps = {n: {} for n in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")}
    x = M._embed(cfg, p, tokens)
    b, s, d = x.shape
    for li in range(cfg.n_layers):
        h = rmsnorm_ref(x, p["norm1"][li], cfg.rms_eps)
        caps["wq"][li] = caps["wk"][li] = caps["wv"][li] = \
            np.asarray(h.reshape(-1, d))
        q, kk, vv = M._project_qkv(cfg, p, li, h)
        a = attention_ref(q, kk, vv)
        a_flat = a.transpose(0, 2, 1, 3).reshape(b, s, -1)
        caps["wo"][li] = np.asarray(a_flat.reshape(-1, a_flat.shape[-1]))
        x = x + a_flat @ p["wo"][li]
        h = rmsnorm_ref(x, p["norm2"][li], cfg.rms_eps)
        caps["w_gate"][li] = caps["w_up"][li] = np.asarray(h.reshape(-1, d))
        mid = jax.nn.silu(h @ p["w_gate"][li]) * (h @ p["w_up"][li])
        caps["w_down"][li] = np.asarray(mid.reshape(-1, mid.shape[-1]))
        x = x + mid @ p["w_down"][li]
    x = rmsnorm_ref(x, p["norm_f"], cfg.rms_eps)
    logits = x @ p["embed"].T
    return caps, np.asarray(logits)


# ---------------------------------------------------------------------------
# Weight track
# ---------------------------------------------------------------------------

WEIGHT_NAMES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


def quantize_weights(cfg: ModelConfig, params, calib, mode="rtn", bits=4,
                     act_fmt="mxint8"):
    """Return a new params dict with MXINT<bits> weights (+MX8 activations
    modeled by quantizing calib-independent weights only — activation
    quantization is dynamic in hardware and simulated at the matmul
    boundary by the A8 logit noise being negligible at these scales).

    mode: 'rtn' | 'gptq' | 'gptq_xclip' | 'gptq_yclip'.
    """
    out = dict(params)
    for name in WEIGHT_NAMES:
        stack = np.asarray(params[name])
        qs = []
        for li in range(cfg.n_layers):
            w = stack[li].T  # [N, K] rows = outputs
            if mode == "rtn":
                q = gptq.rtn_quantize(w, bits=bits)
            elif mode == "gptq":
                q = gptq.gptq_quantize(w, calib[name][li], bits=bits)
            elif mode == "gptq_xclip":
                q = gptq.gptq_quantize(w, calib[name][li], bits=bits,
                                       clip_mode="x")
            elif mode == "gptq_yclip":
                q = gptq.gptq_quantize(w, calib[name][li], bits=bits,
                                       clip_mode="y")
            else:
                raise ValueError(mode)
            qs.append(q.T)
        out[name] = jnp.asarray(np.stack(qs), dtype=jnp.float32)
    return out


# ---------------------------------------------------------------------------
# KV track — transforms plugged into model.generate(kv_transform=...)
# ---------------------------------------------------------------------------

def kv_none():
    return None


def kv_naive(fmt="mxint4"):
    """Naive per-head-dim MX quantization of the whole cache each step."""
    def f(k, v, warm):
        kq = mx.quantize(np.asarray(k), fmt)
        vq = mx.quantize(np.asarray(v), fmt)
        return jnp.asarray(kq), jnp.asarray(vq)
    return f


def kv_quarot(fmt="mxint4"):
    def f(k, v, warm):
        kq, vq = rotation.rotate_quant_kv(np.asarray(k), np.asarray(v), fmt)
        return jnp.asarray(kq), jnp.asarray(vq)
    return f


def kv_baos(variant="mean", alpha=1.0, fmt="mxint4"):
    """BAOS with warm-step calibration: factors are (re)computed on warm
    steps and *reused* for every refinement step of the block."""
    state = baos.BaosState(variant=variant, alpha=alpha)

    def f(k, v, warm):
        if warm or not state.calibrated:
            state.calibrate(np.asarray(k), np.asarray(v))
        kq, vq = state.apply(np.asarray(k), np.asarray(v), fmt)
        return jnp.asarray(kq), jnp.asarray(vq)
    return f


# ---------------------------------------------------------------------------
# Sampling track — logit transforms
# ---------------------------------------------------------------------------

def logits_bf16(z):
    return jnp.asarray(mx.quant_bf16(np.asarray(z)))


def logits_mxfp8(z):
    return jnp.asarray(mx.quant_mxfp8(np.asarray(z)))


LOGIT_TRANSFORMS = {"fp32": None, "bf16": logits_bf16, "mxfp8": logits_mxfp8}


# ---------------------------------------------------------------------------
# Evaluation driver
# ---------------------------------------------------------------------------

def evaluate(cfg: ModelConfig, gc: GenConfig, params, eval_seqs,
             cache_mode="dual", kv_transform=None, logit_mode="fp32",
             v_chunk=128):
    """Generate continuations for eval_seqs' prompts and score them.

    Returns dict with exact_match and token_acc (uses the fast attention
    path; pallas-vs-ref equality is asserted separately in tests).
    """
    from .. import train as T
    M.set_attention_impl("ref")
    try:
        prompts = eval_seqs[:, :gc.prompt_len]
        gen = M.generate(cfg, gc, params, prompts, cache_mode=cache_mode,
                         v_chunk=v_chunk, kv_transform=kv_transform,
                         logit_transform=LOGIT_TRANSFORMS[logit_mode])
        return {
            "exact_match": T.exact_match(cfg, gc, params, eval_seqs, gen),
            "token_acc": T.token_accuracy(cfg, gc, eval_seqs, gen),
        }
    finally:
        M.set_attention_impl("pallas")


def table5_rows(cfg: ModelConfig, gc: GenConfig, params, eval_seqs,
                calib_tokens, cache_modes=("prefix", "dual"),
                alphas=(1.0, 0.9, 0.6), log=print):
    """Run the full Table 5 grid; returns {cache: {row: metrics}}."""
    calib, _ = capture_calib(cfg, params, calib_tokens)
    results = {}
    for cache in cache_modes:
        rows = {}

        def run(name, **kw):
            rows[name] = evaluate(cfg, gc, params if "params_q" not in kw
                                  else kw.pop("params_q"), eval_seqs,
                                  cache_mode=cache, **kw)
            log(f"[{cache}] {name:28s} em={rows[name]['exact_match']:.4f} "
                f"acc={rows[name]['token_acc']:.4f}")

        # baseline + sampling track
        run("baseline")
        run("samp_bf16", logit_mode="bf16")
        run("samp_mxfp8", logit_mode="mxfp8")
        # KV track
        run("kv4", kv_transform=kv_naive())
        run("quarot", kv_transform=kv_quarot())
        for a in alphas:
            run(f"baos_mean_a{a}", kv_transform=kv_baos("mean", a))
            run(f"baos_minmax_a{a}", kv_transform=kv_baos("minmax", a))
        # weight track
        pq_rtn = quantize_weights(cfg, params, calib, mode="rtn")
        rows["w4"] = evaluate(cfg, gc, pq_rtn, eval_seqs, cache_mode=cache)
        log(f"[{cache}] {'w4':28s} em={rows['w4']['exact_match']:.4f}")
        pq_clip = quantize_weights(cfg, params, calib, mode="gptq_xclip")
        rows["w4_xclip"] = evaluate(cfg, gc, pq_clip, eval_seqs,
                                    cache_mode=cache)
        log(f"[{cache}] {'w4_xclip':28s} em={rows['w4_xclip']['exact_match']:.4f}")
        # full stack: best KV (BAOS mean α=1.0) + GPTQ-xclip W4 + BF16 sampling
        rows["full"] = evaluate(cfg, gc, pq_clip, eval_seqs, cache_mode=cache,
                                kv_transform=kv_baos("mean", 1.0),
                                logit_mode="bf16")
        log(f"[{cache}] {'full (KV4+W4+S16)':28s} em={rows['full']['exact_match']:.4f}")
        results[cache] = rows
    return results
