"""DART accuracy-simulator quantization library (paper §4.3–§4.4, §6.1).

numpy/jnp implementations of every quantization scheme Table 5 compares:

* ``mx``        — MX block formats (MXINT4/6/8, MXFP8-E4M3), numpy.
* ``baos``      — Block-Adaptive Online Smoothing with warm-step
                  calibration (mean / minmax centering, α power transform).
* ``rotation``  — QuaRot-style Hadamard rotation baseline adapted to
                  blocked dLLM decoding.
* ``gptq``      — GPTQ with Hessian error propagation and x-clip /
                  y-clip percentile search (PLENA-style, Eq. 7).
* ``harness``   — the Table 5 machinery: KV / weight / sampling tracks
                  over prefix- and dual-cache blocked decoding.
"""

from . import mx, baos, rotation, gptq, harness  # noqa: F401
