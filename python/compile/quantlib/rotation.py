"""QuaRot-style rotation baseline adapted to blocked dLLM decoding.

QuaRot suppresses channel-wise outliers by applying an orthogonal
(Hadamard) rotation along the head dimension before quantization: the
rotated tensor spreads outlier energy evenly across channels, and the
rotation is undone after dequantization (in hardware, fused into the
adjacent matmuls). The accuracy-sim round trip is therefore

    x_hat = Q(x · H) · Hᵀ

which is exactly how the paper evaluates the "QuaRot [3]" rows of
Table 5 against BAOS: an AR-era, *static* smoothing method whose
assumptions (stable activation distributions) dLLM step-wise refinement
violates.
"""

import numpy as np

from . import mx


def hadamard(n: int) -> np.ndarray:
    """Normalized Sylvester–Hadamard matrix; n must be a power of two."""
    if n & (n - 1):
        raise ValueError(f"Hadamard size {n} is not a power of two")
    h = np.ones((1, 1), dtype=np.float64)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return (h / np.sqrt(n)).astype(np.float32)


def rotate_quant(x, fmt="mxint4", block=mx.MX_BLOCK):
    """Fake-quantize along the last (head) dim through a Hadamard rotation."""
    d = x.shape[-1]
    h = hadamard(d)
    xr = np.asarray(x, np.float32) @ h
    q = mx.quantize(xr, fmt, block=min(block, d))
    return q @ h.T


def rotate_quant_kv(k, v, fmt="mxint4", block=mx.MX_BLOCK):
    return rotate_quant(k, fmt, block), rotate_quant(v, fmt, block)
