"""Block-Adaptive Online Smoothing (BAOS) — paper §4.4.

The dLLM-specific KV-cache quantization scheme: the *warm step* of each
generation block (which recomputes KV for the whole sequence anyway) is
used as a zero-overhead online calibration point. Per-channel scaling
factors of shape (B, H, 1, D) are computed by reducing over the sequence
axis, then reused for every refinement step of the block — valid because
the dominant outlier channels are stable within a block (paper §4.4.1).

The normalized tensor (x − c)/f is what enters the MX block quantizer;
attention fuses the inverse scale into the query (Q·f) so the cache is
never unscaled in memory. For K the center c is *free*: softmax is
invariant to the constant-per-query offset Q·cᵀ. For V the output is
re-affined as out·f + c (rows of the attention matrix sum to 1).
"""

import numpy as np

from . import mx


class BaosState:
    """Per-generation-block calibration state (one (c, f) pair per KV)."""

    def __init__(self, variant="mean", alpha=1.0, eps=1e-6):
        assert variant in ("mean", "minmax")
        self.variant = variant
        self.alpha = float(alpha)
        self.eps = eps
        self.c_k = self.f_k = None
        self.c_v = self.f_v = None

    # -- calibration -------------------------------------------------------
    def _factors(self, x):
        """x: [..., S, D] -> (c, f) with shape [..., 1, D] (Eq. 8–9)."""
        x = np.asarray(x, dtype=np.float32)
        xmax = x.max(axis=-2, keepdims=True)
        xmin = x.min(axis=-2, keepdims=True)
        if self.variant == "mean":
            c = x.mean(axis=-2, keepdims=True)
        else:
            c = 0.5 * (xmax + xmin)
        f = np.maximum(xmax - c, c - xmin)
        f = np.maximum(f, self.eps) ** self.alpha
        return c, f

    def calibrate(self, k, v):
        """Warm-step calibration from full K/V: [N_L, B, H, S, D]."""
        self.c_k, self.f_k = self._factors(k)
        self.c_v, self.f_v = self._factors(v)

    @property
    def calibrated(self):
        return self.c_k is not None

    # -- smooth + quantize + unsmooth (accuracy-sim round trip) -------------
    def apply(self, k, v, fmt="mxint4", block=mx.MX_BLOCK):
        """Fake-quantize K/V through the smoothed domain."""
        ks = (np.asarray(k, np.float32) - self.c_k) / self.f_k
        vs = (np.asarray(v, np.float32) - self.c_v) / self.f_v
        kq = mx.quantize(ks, fmt, block=block)
        vq = mx.quantize(vs, fmt, block=block)
        return kq * self.f_k + self.c_k, vq * self.f_v + self.c_v


def outlier_channel_stability(k_warm, k_steps, top=16):
    """Fraction of top-`top` outlier channels (by per-channel max |k|)
    shared between the warm step and each refinement step — the §4.4.1
    profiling statistic (paper reports >70%)."""
    def top_channels(x):
        mag = np.abs(np.asarray(x)).max(axis=tuple(range(x.ndim - 1)))
        return set(np.argsort(-mag)[:top].tolist())

    warm = top_channels(k_warm)
    overlaps = [len(warm & top_channels(ks)) / top for ks in k_steps]
    return float(np.mean(overlaps)) if overlaps else 1.0
