"""MX (microscaling) block formats in numpy — the accuracy-sim twin of
``kernels/mx_quant.py`` and of ``rust/src/quant``.

An MX tensor shares one power-of-two (E8M0) scale per `block` contiguous
elements along the last axis; elements are either symmetric integers
(MXINT) or FP8-E4M3 (MXFP8). All functions are fake-quant round trips
(quantize → dequantize in f32/f64), which is exactly what the accuracy
simulator needs; bit-exact packing lives on the Rust side.
"""

import numpy as np

MX_BLOCK = 32

_E4M3_MAX = 448.0


def _pow2_scale(maxabs, qmax):
    maxabs = np.maximum(maxabs, 1e-30)
    scale = np.exp2(np.floor(np.log2(maxabs / qmax)))
    scale = np.where(maxabs / scale > qmax, scale * 2.0, scale)
    return scale


def _blocked(x, block):
    x = np.asarray(x, dtype=np.float64)
    k = x.shape[-1]
    if k % block != 0:
        raise ValueError(f"last dim {k} not a multiple of MX block {block}")
    return x.reshape(x.shape[:-1] + (k // block, block))


def quant_mxint(x, bits=8, block=MX_BLOCK, clip=1.0):
    """Fake-quantize to MXINT<bits>. ``clip`` shrinks the per-block range
    to [clip*min, clip*max] before the scale is derived (x-clip search)."""
    orig = np.asarray(x).shape
    xb = _blocked(x, block)
    qmax = float(2 ** (bits - 1) - 1)
    maxabs = np.max(np.abs(xb), axis=-1, keepdims=True) * clip
    scale = _pow2_scale(maxabs, qmax)
    q = np.clip(np.round(xb / scale), -qmax, qmax)
    return (q * scale).reshape(orig).astype(np.float32)


def _to_e4m3(y):
    """Round-to-nearest-even E4M3 (saturating, no inf) via bit twiddling."""
    sign = np.signbit(y)
    a = np.abs(y).astype(np.float32)
    a = np.minimum(a, _E4M3_MAX)
    # E4M3: 3 mantissa bits, bias 7, min normal 2^-6, subnormal step 2^-9
    f32 = a.view(np.uint32) if a.flags["C_CONTIGUOUS"] else np.ascontiguousarray(a).view(np.uint32)
    exp = ((f32 >> 23) & 0xFF).astype(np.int32) - 127
    # quantize mantissa to 3 bits with RNE in float domain: snap to grid
    # step = 2^(exp-3) for normals, 2^-9 for subnormals
    step = np.exp2(np.maximum(exp, -7) - 3).astype(np.float32)
    snapped = np.round(a / step) * step
    snapped = np.minimum(snapped, _E4M3_MAX)
    out = np.where(sign, -snapped, snapped)
    return out.astype(np.float32)


def quant_mxfp8(x, block=MX_BLOCK, clip=1.0):
    """Fake-quantize to MXFP8 (E4M3 elements, shared pow-2 block scale)."""
    orig = np.asarray(x).shape
    xb = _blocked(x, block)
    maxabs = np.max(np.abs(xb), axis=-1, keepdims=True) * clip
    scale = _pow2_scale(maxabs, _E4M3_MAX)
    y = _to_e4m3((xb / scale).astype(np.float32))
    return (y * scale).reshape(orig).astype(np.float32)


def quant_bf16(x):
    """Round-trip through bfloat16 (truncate-to-nearest via f32 bits)."""
    a = np.asarray(x, dtype=np.float32)
    bits = np.ascontiguousarray(a).view(np.uint32)
    rounded = (bits + 0x7FFF + ((bits >> 16) & 1)) & 0xFFFF0000
    return rounded.view(np.float32)


def quantize(x, fmt, block=MX_BLOCK, clip=1.0):
    """Dispatch by format name: mxint4/mxint6/mxint8/mxfp8/bf16/fp32."""
    if fmt.startswith("mxint"):
        return quant_mxint(x, bits=int(fmt[5:]), block=block, clip=clip)
    if fmt == "mxfp8":
        return quant_mxfp8(x, block=block, clip=clip)
    if fmt == "bf16":
        return quant_bf16(x)
    if fmt in ("fp32", "fp64", "none"):
        return np.asarray(x, dtype=np.float32)
    raise ValueError(f"unknown MX format {fmt!r}")


def quant_error(x, fmt, **kw):
    """Relative L2 quantization error — the DSE proxy metric."""
    x = np.asarray(x, dtype=np.float32)
    q = quantize(x, fmt, **kw)
    denom = np.linalg.norm(x) + 1e-12
    return float(np.linalg.norm(x - q) / denom)
