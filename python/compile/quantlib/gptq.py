"""GPTQ with MX block scales and clipping-percentile search (paper §4.3).

Implements the PLENA-style weight-quantization flow the paper adopts:
GPTQ's iterative Hessian-based error propagation, processed in
column-blocks aligned with the MX block size (so each block shares one
per-row power-of-two scale), with an optional per-row clipping percentile
search:

* ``x-clip`` — weight-norm guided: pick p minimizing ‖W_b − Q(W_b; p)‖²
* ``y-clip`` — output-norm guided (Eq. 7): pick p minimizing
  ‖X_b (W_b − Q(W_b; p))ᵀ‖²

Conventions: W is [N, K] (rows = output channels), calibration X is
[M, K]; the quantized layer computes y = x Wᵀ.
"""

import numpy as np

from . import mx

DEFAULT_GRID = (1.0, 0.99, 0.95, 0.9, 0.8, 0.7, 0.6, 0.5)


def _block_scales(wb, bits, clip):
    """Per-row shared pow-2 scale for one [N, B] column block.

    clip: [N] per-row percentile multipliers on the representable range.
    """
    qmax = float(2 ** (bits - 1) - 1)
    maxabs = np.max(np.abs(wb), axis=1) * clip
    return mx._pow2_scale(np.maximum(maxabs, 1e-30), qmax), qmax


def _quant_cols(wb, scale, qmax):
    q = np.clip(np.round(wb / scale[:, None]), -qmax, qmax)
    return q * scale[:, None]


def search_clip(wb, xb=None, bits=4, grid=DEFAULT_GRID, mode="x"):
    """Per-row clipping percentile search over one column block.

    mode 'x': minimize weight reconstruction error.
    mode 'y': minimize output reconstruction error ‖X_b ΔWᵀ‖² (Eq. 7);
              factorizes per row as Δw H_b Δwᵀ with H_b = X_bᵀX_b.
    Returns the [N] vector of selected percentiles.
    """
    n = wb.shape[0]
    best_err = np.full(n, np.inf)
    best_p = np.ones(n)
    hb = None
    if mode == "y":
        if xb is None:
            raise ValueError("y-clip requires calibration activations X_b")
        hb = xb.T @ xb  # [B, B]
    for p in grid:
        scale, qmax = _block_scales(wb, bits, np.full(n, p))
        q = _quant_cols(wb, scale, qmax)
        delta = wb - q
        if mode == "x":
            err = np.sum(delta * delta, axis=1)
        else:
            err = np.einsum("nb,bc,nc->n", delta, hb, delta)
        take = err < best_err
        best_err = np.where(take, err, best_err)
        best_p = np.where(take, p, best_p)
    return best_p


def gptq_quantize(w, x, bits=4, block=mx.MX_BLOCK, percdamp=0.01,
                  clip_mode="none", grid=DEFAULT_GRID):
    """Quantize W [N, K] to MXINT<bits> with GPTQ error propagation.

    x: calibration activations [M, K]. clip_mode: 'none' | 'x' | 'y'.
    Returns the fake-quantized (dequantized f32) weight.
    """
    w = np.asarray(w, dtype=np.float64).copy()
    x = np.asarray(x, dtype=np.float64)
    n, k = w.shape
    assert k % block == 0, f"K={k} not a multiple of MX block {block}"

    h = 2.0 * (x.T @ x)                       # Hessian of the quadratic
    dead = np.diag(h) == 0
    h[dead, dead] = 1.0
    w[:, dead] = 0.0
    damp = percdamp * float(np.mean(np.diag(h)))
    h[np.diag_indices(k)] += damp

    # Upper-Cholesky factor of H^-1 (Hinv = Uᵀ U), as in reference GPTQ
    hinv = np.linalg.inv(h)
    hinv = 0.5 * (hinv + hinv.T)  # re-symmetrize against fp error
    hinv_u = np.ascontiguousarray(np.linalg.cholesky(hinv).T)

    q_out = np.zeros_like(w)
    for b0 in range(0, k, block):
        b1 = b0 + block
        wb = w[:, b0:b1]
        if clip_mode == "none":
            clip = np.ones(n)
        else:
            clip = search_clip(wb, x[:, b0:b1], bits=bits, grid=grid,
                               mode=clip_mode)
        scale, qmax = _block_scales(wb, bits, clip)
        err_block = np.zeros_like(wb)
        for j in range(b0, b1):
            wj = w[:, j]
            qj = np.clip(np.round(wj / scale), -qmax, qmax) * scale
            q_out[:, j] = qj
            d = hinv_u[j, j]
            err = (wj - qj) / d
            # propagate within the remaining columns of this block
            if j + 1 < b1:
                w[:, j + 1:b1] -= np.outer(err, hinv_u[j, j + 1:b1])
            err_block[:, j - b0] = err
        # propagate the accumulated block error to all remaining columns
        if b1 < k:
            w[:, b1:] -= err_block @ hinv_u[b0:b1, b1:]
    return q_out.astype(np.float32)


def rtn_quantize(w, bits=4, block=mx.MX_BLOCK, clip_mode="none",
                 grid=DEFAULT_GRID):
    """Round-to-nearest MXINT baseline (the Table 5 'W4' row), with
    optional per-row clip search but no Hessian propagation."""
    w = np.asarray(w, dtype=np.float64)
    n, k = w.shape
    out = np.zeros_like(w)
    for b0 in range(0, k, block):
        wb = w[:, b0:b0 + block]
        if clip_mode == "none":
            clip = np.ones(n)
        else:
            clip = search_clip(wb, None if clip_mode == "x" else wb,
                               bits=bits, grid=grid, mode="x")
        scale, qmax = _block_scales(wb, bits, clip)
        out[:, b0:b0 + block] = _quant_cols(wb, scale, qmax)
    return out.astype(np.float32)
