"""AOT bridge: lower the L2 model to HLO-text artifacts for the Rust runtime.

Emits into ``artifacts/`` (gitignored; `make artifacts` is a no-op when
inputs are unchanged):

* ``full_b{B}.hlo.txt``           — warm step / none-cache step, batch B
* ``refine_dual_b{B}.hlo.txt``    — dual-cache refinement step
* ``refine_prefix_b{B}_n{n}.hlo.txt`` — prefix-cache refinement for block
  n (tail length is shape-static, so one executable per block index —
  "one compiled executable per model variant")
* ``weights.bin``                 — trained parameters, DARTWTS1 format
* ``manifest.json``               — shapes/arg-order/golden vectors the
  Rust runtime + integration tests consume

Interchange is HLO **text**, not serialized HloModuleProto: jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Training is cached in ``artifacts/weights.npz``: delete it (or run with
``--retrain``) to retrain the denoiser.
"""

import argparse
import hashlib
import json
import os
import struct
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import TINY, TINY_GEN, config_dict
from . import model as M
from . import train as T
from .kernels import ref as R

BATCHES = (1, 4)
TRAIN_STEPS = 600
SEED = 0


# ---------------------------------------------------------------------------
# HLO text lowering (see module docstring for why text, not proto)
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants is ESSENTIAL: the default printer elides big
    # literals as `constant({...})`, which xla_extension 0.5.1's text
    # parser silently zero-fills — corrupting any lowered table (e.g.
    # positional encodings) on the Rust runtime path.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # modern metadata attributes (source_end_line, ...) are rejected by
    # the 0.5.1 parser; strip them
    opts.print_metadata = False
    text = comp.as_hlo_module().to_string(opts)
    assert "{...}" not in text, "elided constants survived printing"
    return text


def lower_to_file(fn, args, path):
    text = to_hlo_text(jax.jit(fn).lower(*args))
    with open(path, "w") as f:
        f.write(text)
    return len(text)


# ---------------------------------------------------------------------------
# DARTWTS1 weight container (parsed by rust/src/runtime/weights.rs)
# ---------------------------------------------------------------------------

def write_weights(path, named_arrays):
    """Format: magic 'DARTWTS1', u32 count, then per tensor:
    u32 name_len, name bytes, u32 ndim, u64 dims[ndim], f32 data (LE)."""
    with open(path, "wb") as f:
        f.write(b"DARTWTS1")
        f.write(struct.pack("<I", len(named_arrays)))
        for name, arr in named_arrays:
            a = np.ascontiguousarray(np.asarray(arr), dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", a.ndim))
            for d in a.shape:
                f.write(struct.pack("<Q", d))
            f.write(a.tobytes())


# ---------------------------------------------------------------------------
# Golden vectors for the Rust integration tests
# ---------------------------------------------------------------------------

def _summ(x):
    x = np.asarray(x, dtype=np.float64)
    return {"sum": float(x.sum()), "absmax": float(np.abs(x).max()),
            "first8": [float(v) for v in x.reshape(-1)[:8]]}


def sampling_goldens():
    """Deterministic sampling-engine test vectors (ref oracle outputs)."""
    rng = np.random.default_rng(42)
    b, l, v = 2, 8, 64
    z = (rng.normal(size=(b, l, v)) * 3).astype(np.float32)
    x = rng.integers(0, v, size=(b, l)).astype(np.int32)
    x[:, ::2] = 0  # mask_id = 0 at even positions
    conf, idx = R.stable_max_confidence_ref(jnp.asarray(z.reshape(b * l, v)))
    conf = np.asarray(conf).reshape(b, l)
    idx = np.asarray(idx).reshape(b, l)
    k = np.array([2, 3], dtype=np.int32)
    masks, xnews = [], []
    for bi in range(b):
        m = jnp.asarray(x[bi] == 0)
        tm = R.topk_mask_ref(jnp.asarray(conf[bi]), m, int(k[bi]))
        x0m = R.masked_select_ref(m, jnp.asarray(idx[bi]), jnp.asarray(x[bi]))
        xn = R.masked_select_ref(tm, x0m, jnp.asarray(x[bi]))
        masks.append(np.asarray(tm).astype(np.int32))
        xnews.append(np.asarray(xn))
    return {
        "b": b, "l": l, "v": v, "mask_id": 0,
        "z": z.reshape(-1).tolist(),
        "x": x.reshape(-1).tolist(),
        "k": k.tolist(),
        "conf": conf.reshape(-1).tolist(),
        "argmax": idx.reshape(-1).tolist(),
        "transfer_mask": np.stack(masks).reshape(-1).tolist(),
        "x_new": np.stack(xnews).reshape(-1).tolist(),
    }


def mx_goldens():
    rng = np.random.default_rng(7)
    x = (rng.normal(size=64) * 10).astype(np.float32)
    from .quantlib import mx as qmx
    return {
        "x": x.tolist(),
        "mxint4": qmx.quant_mxint(x, 4).tolist(),
        "mxint8": qmx.quant_mxint(x, 8).tolist(),
        "mxfp8": qmx.quant_mxfp8(x).tolist(),
        "bf16": qmx.quant_bf16(x).tolist(),
    }


def baos_goldens():
    rng = np.random.default_rng(11)
    x = rng.normal(size=(1, 2, 8, 32)).astype(np.float32)
    x[..., 5] *= 12.0  # outlier channel
    from .quantlib import baos as qb
    st = qb.BaosState("mean", 0.9)
    st.calibrate(x, x)
    kq, _ = st.apply(x, x, "mxint4")
    return {
        "shape": list(x.shape),
        "x": x.reshape(-1).tolist(),
        "alpha": 0.9, "variant": "mean",
        "c": st.c_k.reshape(-1).tolist(),
        "f": st.f_k.reshape(-1).tolist(),
        "kq": _summ(kq),
    }


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/manifest.json",
                    help="manifest path; artifacts go to its directory")
    ap.add_argument("--retrain", action="store_true")
    ap.add_argument("--train-steps", type=int, default=TRAIN_STEPS)
    args = ap.parse_args()

    outdir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(outdir, exist_ok=True)
    cfg, gc = TINY, TINY_GEN

    # -- 1. trained weights (cached) ---------------------------------------
    cache = os.path.join(outdir, "weights.npz")
    if os.path.exists(cache) and not args.retrain:
        print(f"loading cached weights from {cache}")
        data = np.load(cache)
        params = {k: jnp.asarray(v) for k, v in data.items()}
    else:
        print(f"training denoiser for {args.train_steps} steps ...")
        params, hist = T.train(cfg, gc, steps=args.train_steps, batch=32,
                               lr=3e-3, log_every=100)
        np.savez(cache, **{k: np.asarray(v) for k, v in params.items()})
        print(f"final loss {hist[-1]:.4f}")

    names = M.param_names(cfg)
    plist = [params[n] for n in names]

    # quick quality gate so a broken training run fails the build
    M.set_attention_impl("ref")
    rng = np.random.default_rng(123)
    seqs = T.make_batch(cfg, gc, rng, 16)
    gen = M.generate(cfg, gc, params, seqs[:, :gc.prompt_len], "dual")
    acc = T.token_accuracy(cfg, gc, seqs, gen)
    em = T.exact_match(cfg, gc, params, seqs, gen)
    M.set_attention_impl("pallas")
    print(f"trained model: token_acc={acc:.3f} exact_match={em:.3f}")
    assert acc > 0.5, "trained model failed the quality gate"

    # -- 2. lower executables ----------------------------------------------
    executables = {}
    nl, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    ltot, L, P = gc.total_len, gc.block_len, gc.prompt_len

    pspecs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in plist]

    for b in BATCHES:
        tok = jax.ShapeDtypeStruct((b, ltot), jnp.int32)
        f = os.path.join(outdir, f"full_b{b}.hlo.txt")
        n = lower_to_file(
            lambda toks, *ps: M.forward_full(cfg, dict(zip(names, ps)), toks),
            (tok, *pspecs), f)
        executables[f"full_b{b}"] = {
            "file": os.path.basename(f), "hlo_chars": n,
            "inputs": [["tokens", "i32", [b, ltot]]] +
                      [[nm, "f32", list(params[nm].shape)] for nm in names],
            "outputs": [["logits", "f32", [b, ltot, cfg.vocab_size]],
                        ["k_cache", "f32", [nl, b, hkv, ltot, dh]],
                        ["v_cache", "f32", [nl, b, hkv, ltot, dh]]],
        }
        print(f"lowered full_b{b} ({n} chars)")

        tok_a = jax.ShapeDtypeStruct((b, L), jnp.int32)
        kv = jax.ShapeDtypeStruct((nl, b, hkv, ltot, dh), jnp.float32)
        bs = jax.ShapeDtypeStruct((), jnp.int32)
        f = os.path.join(outdir, f"refine_dual_b{b}.hlo.txt")
        n = lower_to_file(
            lambda ta, kc, vc, s, *ps: M.forward_refine_dual(
                cfg, dict(zip(names, ps)), ta, kc, vc, s),
            (tok_a, kv, kv, bs, *pspecs), f)
        executables[f"refine_dual_b{b}"] = {
            "file": os.path.basename(f), "hlo_chars": n,
            "inputs": [["tokens_act", "i32", [b, L]],
                       ["k_cache", "f32", [nl, b, hkv, ltot, dh]],
                       ["v_cache", "f32", [nl, b, hkv, ltot, dh]],
                       ["block_start", "i32", []]] +
                      [[nm, "f32", list(params[nm].shape)] for nm in names],
            "outputs": [["logits", "f32", [b, L, cfg.vocab_size]],
                        ["k_act", "f32", [nl, b, hkv, L, dh]],
                        ["v_act", "f32", [nl, b, hkv, L, dh]]],
        }
        print(f"lowered refine_dual_b{b} ({n} chars)")

        for blk in range(gc.n_blocks):
            s_n = gc.block_start(blk)
            tail = ltot - s_n
            tok_t = jax.ShapeDtypeStruct((b, tail), jnp.int32)
            kvp = jax.ShapeDtypeStruct((nl, b, hkv, s_n, dh), jnp.float32)
            f = os.path.join(outdir, f"refine_prefix_b{b}_n{blk}.hlo.txt")
            n = lower_to_file(
                lambda tt, kp, vp, *ps, _s=s_n: M.forward_refine_prefix(
                    cfg, dict(zip(names, ps)), tt, kp, vp, _s, L),
                (tok_t, kvp, kvp, *pspecs), f)
            executables[f"refine_prefix_b{b}_n{blk}"] = {
                "file": os.path.basename(f), "hlo_chars": n,
                "inputs": [["tokens_tail", "i32", [b, tail]],
                           ["k_prefix", "f32", [nl, b, hkv, s_n, dh]],
                           ["v_prefix", "f32", [nl, b, hkv, s_n, dh]]] +
                          [[nm, "f32", list(params[nm].shape)] for nm in names],
                "outputs": [["logits", "f32", [b, L, cfg.vocab_size]]],
            }
            print(f"lowered refine_prefix_b{b}_n{blk} ({n} chars)")

    # -- 3. weights + goldens ----------------------------------------------
    write_weights(os.path.join(outdir, "weights.bin"),
                  [(nm, params[nm]) for nm in names])

    # model-level golden: fixed tokens → output summaries (fast ref attn —
    # pallas-vs-ref equality is asserted separately in python/tests)
    M.set_attention_impl("ref")
    tok_g = np.arange(4 * ltot, dtype=np.int32).reshape(4, ltot) % cfg.vocab_size
    lg, kc, vc = M.forward_full(cfg, params, jnp.asarray(tok_g))
    conf_g, idx_g = R.stable_max_confidence_ref(
        lg[:, P:P + L, :].reshape(-1, cfg.vocab_size))

    # end-to-end generation goldens: fixed prompt → full blocked-diffusion
    # output per cache mode (the Rust coordinator's parity reference)
    gen_prompt = (np.arange(P, dtype=np.int32) * 7 + 11) % (cfg.vocab_size - 8) + 4
    gen_golden = {"prompt": gen_prompt.tolist()}
    for mode in ("none", "prefix", "dual"):
        out = M.generate(cfg, gc, params,
                         jnp.asarray(gen_prompt)[None, :], cache_mode=mode)
        gen_golden[mode] = np.asarray(out)[0].tolist()
    M.set_attention_impl("pallas")

    manifest = {
        "format": "dart-manifest-v1",
        "config": config_dict(cfg, gc),
        "param_order": names,
        "batches": list(BATCHES),
        "executables": executables,
        "weights_file": "weights.bin",
        "train": {"steps": args.train_steps, "token_acc": acc,
                  "exact_match": em},
        "goldens": {
            "full_tokens_mod": cfg.vocab_size,
            "full_logits": _summ(lg),
            "full_k": _summ(kc),
            "full_v": _summ(vc),
            "block0_conf": _summ(conf_g),
            "block0_argmax_first8": [int(v) for v in np.asarray(idx_g)[:8]],
            "generation": gen_golden,
            "sampling": sampling_goldens(),
            "mx": mx_goldens(),
            "baos": baos_goldens(),
        },
    }
    blob = json.dumps(manifest, indent=1)
    with open(args.out, "w") as f:
        f.write(blob)
    digest = hashlib.sha256(blob.encode()).hexdigest()[:16]
    print(f"wrote {args.out} ({len(blob)} bytes, sha {digest})")


if __name__ == "__main__":
    main()
