"""L2: LLaDA-style masked-diffusion transformer in JAX (paper §2, Alg. 1).

Structure mirrors the paper's execution model exactly:

* bidirectional attention (no causal mask) via the L1 Pallas
  FlashAttention kernel;
* blocked-diffusion generation (Fast-dLLM): each generation block starts
  with a *warm step* over the full sequence that (re)computes the KV
  cache, followed by T−1 *refinement steps* under one of three cache
  strategies — ``none`` (recompute everything), ``prefix`` (cache prefix
  only, recompute active+suffix) or ``dual`` (full cache, in-place active
  block replacement, frozen stale suffix);
* the sampling stage (Alg. 2) via the L1 sampling kernels.

Three entry points are AOT-lowered by ``aot.py`` into HLO-text artifacts
executed from Rust: ``forward_full`` (warm steps / none-cache steps),
``forward_refine_dual`` (dual-cache refinement) and
``forward_refine_prefix`` (prefix-cache refinement, one executable per
block index because the tail length is shape-static).

Everything here is build-time python; the request path is Rust-only.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig, GenConfig
from .kernels.attention import flash_attention
from .kernels.sampling import sample_block
from .kernels.ref import attention_ref, rmsnorm_ref as rmsnorm

# Attention implementation used by the forward passes. The AOT path uses
# the L1 Pallas kernel (the deliverable); the training loop swaps in the
# mathematically identical pure-jnp oracle, which jits ~100x faster under
# CPU interpret mode (numerics agree to fp32 rounding — asserted in
# python/tests/test_attention.py).
_ATTN_IMPL = flash_attention


def set_attention_impl(name: str):
    """Select 'pallas' (default, used for AOT) or 'ref' (fast jnp path)."""
    global _ATTN_IMPL
    _ATTN_IMPL = {"pallas": flash_attention, "ref": attention_ref}[name]


def _attention(q, k, v):
    return _ATTN_IMPL(q, k, v)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    """Initialize parameters as a flat dict of stacked per-layer arrays.

    Stacking (leading N_L axis) keeps the AOT executables' argument count
    small and lets the Rust runtime feed a fixed tensor tuple.
    """
    k = iter(jax.random.split(key, 32))
    d, dh = cfg.d_model, cfg.d_head
    hq, hkv, nl, f = cfg.n_heads, cfg.n_kv_heads, cfg.n_layers, cfg.d_ff

    def init(key, shape, fan_in):
        return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(jnp.float32)

    p = {
        "embed": init(next(k), (cfg.vocab_size, d), d),
        "wq": init(next(k), (nl, d, hq * dh), d),
        "wk": init(next(k), (nl, d, hkv * dh), d),
        "wv": init(next(k), (nl, d, hkv * dh), d),
        "wo": init(next(k), (nl, hq * dh, d), hq * dh),
        "norm1": jnp.ones((nl, d), jnp.float32),
        "norm2": jnp.ones((nl, d), jnp.float32),
        "norm_f": jnp.ones((d,), jnp.float32),
    }
    if cfg.is_moe:
        e = cfg.n_experts
        p["gate"] = init(next(k), (nl, d, e), d)
        p["w_gate"] = init(next(k), (nl, e, d, f), d)
        p["w_up"] = init(next(k), (nl, e, d, f), d)
        p["w_down"] = init(next(k), (nl, e, f, d), f)
    else:
        p["w_gate"] = init(next(k), (nl, d, f), d)
        p["w_up"] = init(next(k), (nl, d, f), d)
        p["w_down"] = init(next(k), (nl, f, d), f)
    return p


PARAM_ORDER = ["embed", "wq", "wk", "wv", "wo", "norm1", "norm2", "norm_f",
               "w_gate", "w_up", "w_down"]
PARAM_ORDER_MOE = PARAM_ORDER + ["gate"]


def param_names(cfg: ModelConfig):
    return PARAM_ORDER_MOE if cfg.is_moe else PARAM_ORDER


def params_to_list(cfg, params):
    return [params[n] for n in param_names(cfg)]


def params_from_list(cfg, lst):
    return dict(zip(param_names(cfg), lst))


# ---------------------------------------------------------------------------
# Positional encoding — fixed sinusoidal added to embeddings (absolute
# positions are shared between warm and refine passes via `pos_offset`).
# ---------------------------------------------------------------------------

def positional(d_model: int, positions):
    # NB: numpy (not jnp) constants — jnp.arange lowers to an HLO iota(),
    # which xla_extension 0.5.1's text parser mis-executes as zeros on
    # the Rust runtime path. Constants round-trip correctly.
    inv = jnp.exp(-np.arange(0, d_model, 2) / d_model * np.log(10000.0))
    ang = positions[:, None].astype(jnp.float32) * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# FFN (dense SwiGLU or MoE with top-k gating, paper Alg. 1 line 10)
# ---------------------------------------------------------------------------

def _ffn_dense(cfg, p, li, x):
    h = jax.nn.silu(x @ p["w_gate"][li]) * (x @ p["w_up"][li])
    return h @ p["w_down"][li]


def _ffn_moe(cfg, p, li, x):
    """Top-k-of-E MoE. Dense formulation (all experts computed, gated sum)
    — exact at tiny scale; the sparsity only matters for the performance
    models, which account for it analytically (activated-expert FLOPs)."""
    scores = jax.nn.softmax(x @ p["gate"][li], axis=-1)       # [B,S,E]
    topv, topi = jax.lax.top_k(scores, cfg.top_k_experts)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    # per-expert dense FFN
    h = jnp.einsum("bsd,edf->bsef", x, p["w_gate"][li])
    u = jnp.einsum("bsd,edf->bsef", x, p["w_up"][li])
    y = jnp.einsum("bsef,efd->bsed", jax.nn.silu(h) * u, p["w_down"][li])
    onehot = jax.nn.one_hot(topi, cfg.n_experts, dtype=x.dtype)  # [B,S,K,E]
    w = jnp.einsum("bsk,bske->bse", topv, onehot)                # [B,S,E]
    return jnp.einsum("bse,bsed->bsd", w, y)


def _ffn(cfg, p, li, x):
    return _ffn_moe(cfg, p, li, x) if cfg.is_moe else _ffn_dense(cfg, p, li, x)


# ---------------------------------------------------------------------------
# Transformer layers
# ---------------------------------------------------------------------------

def _project_qkv(cfg, p, li, x):
    b, s, _ = x.shape
    q = (x @ p["wq"][li]).reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    kk = (x @ p["wk"][li]).reshape(b, s, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    vv = (x @ p["wv"][li]).reshape(b, s, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    return q, kk, vv


def _attn_out(cfg, p, li, a):
    b, h, s, dh = a.shape
    return a.transpose(0, 2, 1, 3).reshape(b, s, h * dh) @ p["wo"][li]


def _embed(cfg, p, tokens, pos_offset=0):
    x = p["embed"][tokens]
    s = tokens.shape[1]
    pos = jnp.asarray(np.arange(s)) + pos_offset  # constant, not iota
    return x + positional(cfg.d_model, pos)[None, :, :]


def forward_full(cfg: ModelConfig, params, tokens):
    """Full-sequence bidirectional forward (warm step / none-cache step).

    tokens: [B, S] int32. Returns (logits [B,S,V] f32,
    k_cache, v_cache [N_L, B, Hkv, S, Dh] f32).
    """
    p = params
    x = _embed(cfg, p, tokens)
    ks, vs = [], []
    for li in range(cfg.n_layers):
        h = rmsnorm(x, p["norm1"][li], cfg.rms_eps)
        q, kk, vv = _project_qkv(cfg, p, li, h)
        ks.append(kk)
        vs.append(vv)
        a = _attention(q, kk, vv)
        x = x + _attn_out(cfg, p, li, a)
        h = rmsnorm(x, p["norm2"][li], cfg.rms_eps)
        x = x + _ffn(cfg, p, li, h)
    x = rmsnorm(x, p["norm_f"], cfg.rms_eps)
    logits = x @ p["embed"].T  # tied lm head
    return logits, jnp.stack(ks), jnp.stack(vs)


def forward_refine_dual(cfg: ModelConfig, params, tokens_act, k_cache, v_cache,
                        block_start):
    """Dual-cache refinement step (Fig. 4b).

    Only the active block [B, L] is processed; its KV replaces the cached
    slice in place (dynamic_update_slice at ``block_start``); prefix and
    suffix KV stay frozen from the warm step (the suffix is *stale*).

    tokens_act: [B, L]; k_cache/v_cache: [N_L, B, Hkv, L_tot, Dh];
    block_start: scalar int32. Returns (logits [B,L,V], k_act, v_act
    [N_L, B, Hkv, L, Dh]) — the caller (the Rust KV manager) commits the
    active KV into its cache copy.
    """
    p = params
    x = _embed(cfg, p, tokens_act, pos_offset=block_start)
    kas, vas = [], []
    for li in range(cfg.n_layers):
        h = rmsnorm(x, p["norm1"][li], cfg.rms_eps)
        q, kk, vv = _project_qkv(cfg, p, li, h)
        kas.append(kk)
        vas.append(vv)
        kc = jax.lax.dynamic_update_slice(
            k_cache[li], kk, (0, 0, block_start, 0))
        vc = jax.lax.dynamic_update_slice(
            v_cache[li], vv, (0, 0, block_start, 0))
        a = _attention(q, kc, vc)
        x = x + _attn_out(cfg, p, li, a)
        h = rmsnorm(x, p["norm2"][li], cfg.rms_eps)
        x = x + _ffn(cfg, p, li, h)
    x = rmsnorm(x, p["norm_f"], cfg.rms_eps)
    logits = x @ p["embed"].T
    return logits, jnp.stack(kas), jnp.stack(vas)


def forward_refine_prefix(cfg: ModelConfig, params, tokens_tail, k_prefix,
                          v_prefix, prefix_len: int, block_len: int):
    """Prefix-cache refinement step (Fig. 4a).

    The sequence from the active block onward (``tokens_tail``,
    [B, L_tot − prefix_len]) is reprocessed: active-block and suffix KV
    are recomputed fresh each step (full context freshness) but not
    cached. Attention runs over [prefix KV ‖ fresh tail KV].

    Returns logits for the active block only: [B, block_len, V].
    """
    p = params
    x = _embed(cfg, p, tokens_tail, pos_offset=prefix_len)
    for li in range(cfg.n_layers):
        h = rmsnorm(x, p["norm1"][li], cfg.rms_eps)
        q, kk, vv = _project_qkv(cfg, p, li, h)
        kc = jnp.concatenate([k_prefix[li], kk], axis=2)
        vc = jnp.concatenate([v_prefix[li], vv], axis=2)
        a = _attention(q, kc, vc)
        x = x + _attn_out(cfg, p, li, a)
        h = rmsnorm(x, p["norm2"][li], cfg.rms_eps)
        x = x + _ffn(cfg, p, li, h)
    x = rmsnorm(x, p["norm_f"], cfg.rms_eps)
    logits = x @ p["embed"].T
    return logits[:, :block_len, :]


# ---------------------------------------------------------------------------
# Sampling schedule (paper Alg. 2, get_num_transfer_tokens)
# ---------------------------------------------------------------------------

def num_transfer_tokens(block_len: int, steps: int):
    """Tokens committed at each denoising step: L/T each, remainder to the
    earliest steps (LLaDA reference schedule)."""
    base, rem = divmod(block_len, steps)
    return [base + (1 if t < rem else 0) for t in range(steps)]


# ---------------------------------------------------------------------------
# Reference blocked-diffusion generation loop (python golden; the Rust
# coordinator re-implements exactly this control flow on the PJRT path)
# ---------------------------------------------------------------------------

def generate(cfg: ModelConfig, gc: GenConfig, params, prompt,
             cache_mode="dual", v_chunk=128, kv_transform=None,
             logit_transform=None):
    """Generate ``gc.gen_len`` tokens after ``prompt`` [B, prompt_len].

    cache_mode: 'none' | 'prefix' | 'dual'. ``kv_transform`` optionally
    maps (k_cache, v_cache, warm: bool) -> (k, v) — the hook the
    quantization accuracy harness uses to fake-quantize the KV cache
    (naive, rotated, or BAOS-smoothed) exactly where the hardware would.
    ``logit_transform`` (logits -> logits) models the sampling-stage
    precision (FP64 reference / BF16 / MXFP8, paper §6.1).

    Returns the full [B, L_tot] sequence.
    """
    b = prompt.shape[0]
    x = jnp.full((b, gc.total_len), cfg.mask_id, dtype=jnp.int32)
    x = x.at[:, :gc.prompt_len].set(prompt)
    ks = num_transfer_tokens(gc.block_len, gc.steps_per_block)

    for n in range(gc.n_blocks):
        s_n, e_n = gc.block_start(n), gc.block_end(n)
        k_cache = v_cache = None
        for t in range(gc.steps_per_block):
            k_t = jnp.full((b,), ks[t], dtype=jnp.int32)
            if t == 0 or cache_mode == "none":
                # warm step (or uncached step): full sequence
                logits_all, k_cache, v_cache = forward_full(cfg, params, x)
                if kv_transform is not None:
                    k_cache, v_cache = kv_transform(k_cache, v_cache, True)
                logits = logits_all[:, s_n:e_n, :]
            elif cache_mode == "dual":
                logits, ka, va = forward_refine_dual(
                    cfg, params, x[:, s_n:e_n], k_cache, v_cache,
                    jnp.int32(s_n))
                k_cache = jax.lax.dynamic_update_slice(
                    k_cache, ka, (0, 0, 0, s_n, 0))
                v_cache = jax.lax.dynamic_update_slice(
                    v_cache, va, (0, 0, 0, s_n, 0))
                if kv_transform is not None:
                    k_cache, v_cache = kv_transform(k_cache, v_cache, False)
            else:  # prefix
                logits = forward_refine_prefix(
                    cfg, params, x[:, s_n:], k_cache[:, :, :, :s_n, :],
                    v_cache[:, :, :, :s_n, :], s_n, gc.block_len)
            if logit_transform is not None:
                logits = logit_transform(logits)
            xb, _, _ = sample_block(logits, x[:, s_n:e_n], k_t, cfg.mask_id,
                                    v_chunk=v_chunk)
            x = x.at[:, s_n:e_n].set(xb)
    return x
