"""Table 5 driver: quantization quality of the trained tiny dLLM across
sampling / KV / weight tracks under prefix- and dual-cache decoding.

Run with ``make table5``; paste the printed table into EXPERIMENTS.md.
"""

import os

import numpy as np
import jax.numpy as jnp

from .configs import TINY, TINY_GEN
from . import model as M
from . import train as T
from .quantlib import harness as H


def main(n_eval=24):
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    wpath = os.path.join(os.path.dirname(here), "artifacts", "weights.npz")
    if not os.path.exists(wpath):
        raise SystemExit("run `make artifacts` first (trained weights needed)")
    params = {k: jnp.asarray(v) for k, v in np.load(wpath).items()}

    rng = np.random.default_rng(2024)
    eval_seqs = T.make_batch(TINY, TINY_GEN, rng, n_eval)
    calib_tokens = T.make_batch(TINY, TINY_GEN, rng, 8)

    results = H.table5_rows(TINY, TINY_GEN, params, eval_seqs, calib_tokens)

    print("\n===== Table 5 (reproduction; exact-match on synthetic tasks) =====")
    rows = sorted({r for c in results.values() for r in c})
    hdr = f"{'configuration':30s}" + "".join(
        f"  {c:>14s}" for c in results)
    print(hdr)
    for r in rows:
        line = f"{r:30s}"
        for c in results:
            m = results[c].get(r)
            line += f"  {m['exact_match']:>7.4f}/{m['token_acc']:.2f}" if m else " " * 16
        print(line)


if __name__ == "__main__":
    main()
