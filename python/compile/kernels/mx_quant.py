"""L1 Pallas kernel: MX block fake-quantization (paper §3.1.1, §4.3).

Simulates the asymmetric data path of the DART Transformer Engine: BF16
activations are dynamically quantized to an MX format (shared
power-of-two scale per 32-element block) at the systolic-array boundary.
The kernel computes the per-block E8M0 scale and the quantize→dequantize
round trip in one pass, mirroring the hardware's quantize unit.

Checked against ref.mxint_quant_ref / ref.mxfp8_quant_ref.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MX_BLOCK


def _mx_kernel(x_ref, o_ref, *, block: int, qmax: float, mode: str):
    """One row: quantize each `block`-wide group with a shared pow-2 scale."""
    x = x_ref[...].astype(jnp.float32)
    k = x.shape[0]
    xb = x.reshape(k // block, block)
    maxabs = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1, keepdims=True), 1e-30)
    e = jnp.floor(jnp.log2(maxabs / qmax))
    scale = jnp.exp2(e)
    scale = jnp.where(maxabs / scale > qmax, scale * 2.0, scale)
    if mode == "int":
        q = jnp.clip(jnp.round(xb / scale), -qmax, qmax)
        y = q * scale
    else:  # fp8 (E4M3 element type)
        y = (xb / scale).astype(jnp.float8_e4m3fn).astype(jnp.float32) * scale
    o_ref[...] = y.reshape(k)


def _call(x, block, qmax, mode):
    orig = x.shape
    k = orig[-1]
    assert k % block == 0, f"last dim {k} not a multiple of MX block {block}"
    rows = 1
    for s in orig[:-1]:
        rows *= s
    x2 = x.reshape(rows, k)
    kern = functools.partial(_mx_kernel, block=block, qmax=qmax, mode=mode)
    y = pl.pallas_call(
        kern,
        grid=(rows,),
        in_specs=[pl.BlockSpec((None, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((None, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, k), jnp.float32),
        interpret=True,
    )(x2)
    return y.reshape(orig)


@functools.partial(jax.jit, static_argnames=("bits", "block"))
def mxint_quant(x, bits=8, block=MX_BLOCK):
    """Fake-quantize to MXINT<bits> along the last axis (Pallas)."""
    qmax = float(2 ** (bits - 1) - 1)
    return _call(x, block, qmax, "int")


@functools.partial(jax.jit, static_argnames=("block",))
def mxfp8_quant(x, block=MX_BLOCK):
    """Fake-quantize to MXFP8-E4M3 along the last axis (Pallas)."""
    return _call(x, block, 448.0, "fp8")
