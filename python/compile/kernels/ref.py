"""Pure-jnp correctness oracles for every L1 Pallas kernel.

These are the CORE correctness signal of the python layer: each Pallas
kernel in this package is checked elementwise against the function of the
same name here (pytest + hypothesis sweeps in ``python/tests``), and the
Rust golden models are checked against I/O vectors generated from these
oracles (``artifacts/manifest.json``).
"""

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, scale=None):
    """Bidirectional (no causal mask) multi-head attention.

    q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D] with Hq % Hkv == 0 (GQA).
    Returns [B, Hq, Sq, D] in float32.
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kq.astype(jnp.float32)) * scale
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vq.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Stable-Max sampling primitives (paper §3.2, Eq. 3)
# ---------------------------------------------------------------------------

def stable_max_confidence_ref(z):
    """Per-position Stable-Max confidence and argmax index.

    z: [..., V] logits. Returns (conf[...], idx[...]) where
    conf = softmax(z)[argmax] = 1 / sum_j exp(z_j - max z).
    """
    z = z.astype(jnp.float32)
    m = jnp.max(z, axis=-1)
    idx = jnp.argmax(z, axis=-1).astype(jnp.int32)
    denom = jnp.sum(jnp.exp(z - m[..., None]), axis=-1)
    return (1.0 / denom).astype(jnp.float32), idx


def topk_mask_ref(conf, mask, k):
    """Boolean transfer mask selecting the top-k masked positions.

    conf: [L] float confidence; mask: [L] bool (True = still masked,
    eligible); k: python int. Ties broken toward the lower index, matching
    the streaming insertion comparator (strict `>` replacement).
    """
    neg = jnp.finfo(jnp.float32).min
    eligible = jnp.where(mask, conf.astype(jnp.float32), neg)
    L = conf.shape[0]
    k = min(int(k), L)
    if k == 0:
        return jnp.zeros((L,), dtype=bool)
    # top_k with index tie-breaking identical to first-come insertion
    _, idx = jax.lax.top_k(eligible, k)
    out = jnp.zeros((L,), dtype=bool).at[idx].set(True)
    # positions that were not eligible can never transfer
    return jnp.logical_and(out, mask)


def masked_select_ref(mask, a, b):
    """V_SELECT_INT: elementwise where(mask, a, b) over int32."""
    return jnp.where(mask, a, b).astype(jnp.int32)


# ---------------------------------------------------------------------------
# MX block quantization (OCP microscaling, shared power-of-two scale)
# ---------------------------------------------------------------------------

MX_BLOCK = 32


def _pow2_scale(maxabs, qmax):
    """Per-block power-of-two scale mapping maxabs onto qmax."""
    maxabs = jnp.maximum(maxabs, 1e-30)
    e = jnp.floor(jnp.log2(maxabs / qmax))
    scale = jnp.exp2(e)
    # round scale up so maxabs/scale <= qmax always holds
    scale = jnp.where(maxabs / scale > qmax, scale * 2.0, scale)
    return scale


def mxint_quant_ref(x, bits=8, block=MX_BLOCK):
    """Fake-quantize to MXINT<bits> along the last axis.

    Elements are symmetric ints in [-(2^(b-1)-1), 2^(b-1)-1] with one
    shared power-of-two scale per `block` contiguous elements.
    """
    x = x.astype(jnp.float32)
    orig = x.shape
    k = orig[-1]
    assert k % block == 0, f"last dim {k} not a multiple of {block}"
    xb = x.reshape(orig[:-1] + (k // block, block))
    qmax = float(2 ** (bits - 1) - 1)
    maxabs = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = _pow2_scale(maxabs, qmax)
    q = jnp.clip(jnp.round(xb / scale), -qmax, qmax)
    return (q * scale).reshape(orig)


def mxfp8_quant_ref(x, block=MX_BLOCK):
    """Fake-quantize to MXFP8 (E4M3 elements, shared pow-2 block scale)."""
    x = x.astype(jnp.float32)
    orig = x.shape
    k = orig[-1]
    assert k % block == 0
    xb = x.reshape(orig[:-1] + (k // block, block))
    f8max = 448.0  # E4M3 max normal
    maxabs = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = _pow2_scale(maxabs, f8max)
    y = (xb / scale).astype(jnp.float8_e4m3fn).astype(jnp.float32)
    return (y * scale).reshape(orig)


def bf16_quant_ref(x):
    """Round-trip through bfloat16 (the 'S16' sampling precision)."""
    return x.astype(jnp.bfloat16).astype(jnp.float32)


# ---------------------------------------------------------------------------
# BAOS — Block-Adaptive Online Smoothing (paper §4.4)
# ---------------------------------------------------------------------------

def baos_factors_ref(x, alpha=1.0, variant="mean", eps=1e-6):
    """Warm-step calibration factors from x: [B, H, S, D].

    Returns (c, f), both [B, H, 1, D]. `variant` is 'mean' (temporal-mean
    center, paper Eq. 8) or 'minmax' (midpoint center). f is raised to
    the power alpha (paper Eq. 9).
    """
    x = x.astype(jnp.float32)
    xmax = jnp.max(x, axis=2, keepdims=True)
    xmin = jnp.min(x, axis=2, keepdims=True)
    if variant == "mean":
        c = jnp.mean(x, axis=2, keepdims=True)
    elif variant == "minmax":
        c = 0.5 * (xmax + xmin)
    else:
        raise ValueError(f"unknown BAOS variant {variant!r}")
    f = jnp.maximum(xmax - c, c - xmin)
    f = jnp.maximum(f, eps) ** alpha
    return c, f


def baos_normalize_ref(x, c, f):
    """(x - c) / f — applied before the MX block quantizer."""
    return (x.astype(jnp.float32) - c) / f


def baos_denormalize_ref(xs, c, f):
    return xs.astype(jnp.float32) * f + c


# ---------------------------------------------------------------------------
# RMSNorm / SwiGLU (transformer building blocks; L2 uses these directly)
# ---------------------------------------------------------------------------

def rmsnorm_ref(x, g, eps=1e-5):
    x = x.astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def swiglu_ref(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down
