"""L1 Pallas kernels: the diffusion sampling engine (paper §3.2, Alg. 2).

These kernels mirror the four hardware-visible phases of the DART
Vector-Scalar Sampling Engine:

  Phase 1  (HBM → Vector → Scalar): ``confidence_argmax`` — the Stable-Max
           decomposition. V_RED_MAX_IDX finds (m, i*) in one pass, the
           logit buffer is overwritten in place with exp(z - m)
           (V_EXP_V), V_RED_SUM accumulates the denominator, and S_RECIP
           yields the confidence 1/Σ exp(z_j − m). The vocabulary is
           streamed in ``v_chunk`` tiles — the kernel's fori_loop is the
           HBM→VMEM chunk schedule (Eq. 4's V_chunk term).
  Phase 3  (Scalar → Vector → Scalar): ``topk_mask`` — the O(k)-area
           streaming insertion comparator (V_TOPK_MASK).
  Phase 4  (Integer masked update): ``masked_select`` — V_SELECT_INT.

Each kernel is verified against ``ref.py`` in python/tests, and the same
semantics are re-implemented by the Rust golden sampling engine
(rust/src/sampling), cross-checked through artifacts/manifest.json.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Phase 1: Stable-Max confidence + fused max-with-index
# ---------------------------------------------------------------------------

def _confidence_kernel(z_ref, conf_ref, idx_ref, *, v_chunk: int):
    """One (position,) program: stream the V-long logit row in chunks.

    Pass 1 (V_RED_MAX_IDX): running (max, argmax) over chunks.
    Pass 2 (V_EXP_V + V_RED_SUM): running Σ exp(z − m).
    S_RECIP: conf = 1 / Σ. No global synchronization between passes —
    each chunk's partial reduction folds into a scalar carry.
    """
    v = z_ref.shape[0]
    n_chunks = v // v_chunk

    def max_body(i, carry):
        m, mi = carry
        zc = pl.load(z_ref, (pl.ds(i * v_chunk, v_chunk),)).astype(jnp.float32)
        cm = jnp.max(zc)
        ci = jnp.argmax(zc).astype(jnp.int32) + i * v_chunk
        take = cm > m  # strict '>' — ties keep the earlier index
        return jnp.where(take, cm, m), jnp.where(take, ci, mi)

    m, mi = jax.lax.fori_loop(
        0, n_chunks, max_body,
        (jnp.float32(-jnp.inf), jnp.int32(0)))

    def sum_body(i, acc):
        zc = pl.load(z_ref, (pl.ds(i * v_chunk, v_chunk),)).astype(jnp.float32)
        return acc + jnp.sum(jnp.exp(zc - m))

    denom = jax.lax.fori_loop(0, n_chunks, sum_body, jnp.float32(0.0))
    conf_ref[0] = 1.0 / denom
    idx_ref[0] = mi


@functools.partial(jax.jit, static_argnames=("v_chunk",))
def confidence_argmax(z, v_chunk=128):
    """Stable-Max confidence + argmax over the vocabulary axis.

    z: [N, V] logits (N = flattened B×L positions). Returns
    (conf[N] f32, idx[N] i32). ``v_chunk`` is the streaming tile size
    (paper's V_chunk knob); must divide V.
    """
    n, v = z.shape
    v_chunk = min(v_chunk, v)
    assert v % v_chunk == 0, f"V={v} not a multiple of v_chunk={v_chunk}"
    kernel = functools.partial(_confidence_kernel, v_chunk=v_chunk)
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((None, v), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=True,
    )(z)


# ---------------------------------------------------------------------------
# Phase 3: streaming insertion top-k (V_TOPK_MASK)
# ---------------------------------------------------------------------------

def _topk_mask_kernel(conf_ref, mask_ref, k_ref, out_ref, *, l: int, kmax: int):
    """Streaming insertion over L confidence scalars.

    Maintains a k-deep sorted register file of (value, index) pairs — the
    paper's O(k)-area comparator chain. An element enters the chain only
    with a strict '>' comparison, so ties resolve to the earliest index,
    matching ref.topk_mask_ref and the Rust implementation.
    """
    neg = jnp.finfo(jnp.float32).min
    conf = conf_ref[...].astype(jnp.float32)
    mask = mask_ref[...]
    k = k_ref[0]
    eligible = jnp.where(mask != 0, conf, neg)

    vals0 = jnp.full((kmax,), neg, dtype=jnp.float32)
    idxs0 = jnp.full((kmax,), -1, dtype=jnp.int32)

    def insert(i, carry):
        vals, idxs = carry
        v = eligible[i]

        def shift(j, c):
            vs, ix, cur_v, cur_i = c
            # compare against slot j; on strict win, displace and carry on
            win = cur_v > vs[j]
            new_vs = vs.at[j].set(jnp.where(win, cur_v, vs[j]))
            new_ix = ix.at[j].set(jnp.where(win, cur_i, ix[j]))
            nxt_v = jnp.where(win, vs[j], cur_v)
            nxt_i = jnp.where(win, idxs_at(ix, j, win), cur_i)
            return new_vs, new_ix, nxt_v, nxt_i

        def idxs_at(ix, j, win):
            return ix[j]

        vals, idxs, _, _ = jax.lax.fori_loop(
            0, kmax, shift, (vals, idxs, v, jnp.int32(i)))
        return vals, idxs

    vals, idxs = jax.lax.fori_loop(0, l, insert, (vals0, idxs0))

    # emit boolean transfer mask for the first k chain slots
    out = jnp.zeros((l,), dtype=jnp.int32)

    def emit(j, out):
        valid = jnp.logical_and(j < k, idxs[j] >= 0)
        valid = jnp.logical_and(valid, vals[j] > neg)
        safe = jnp.clip(idxs[j], 0, l - 1)
        return out.at[safe].set(jnp.where(valid, 1, out[safe]))

    out = jax.lax.fori_loop(0, kmax, emit, out)
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("kmax",))
def topk_mask(conf, mask, k, kmax=None):
    """V_TOPK_MASK over a batch of rows.

    conf: [B, L] f32; mask: [B, L] int32 (nonzero = masked/eligible);
    k: [B] int32 per-row transfer counts. Returns [B, L] int32 boolean
    mask. ``kmax`` bounds the comparator chain depth (defaults to L).
    """
    b, l = conf.shape
    if kmax is None:
        kmax = l
    kernel = functools.partial(_topk_mask_kernel, l=l, kmax=kmax)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((None, l), lambda i: (i, 0)),
            pl.BlockSpec((None, l), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((None, l), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l), jnp.int32),
        interpret=True,
    )(conf, mask, k)


# ---------------------------------------------------------------------------
# Phase 4: masked integer select (V_SELECT_INT)
# ---------------------------------------------------------------------------

def _select_kernel(m_ref, a_ref, b_ref, o_ref):
    o_ref[...] = jnp.where(m_ref[...] != 0, a_ref[...], b_ref[...])


@jax.jit
def masked_select(mask, a, b):
    """V_SELECT_INT: out[i] = mask[i] ? a[i] : b[i] over int32 rows."""
    rows, l = mask.shape
    return pl.pallas_call(
        _select_kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec((None, l), lambda i: (i, 0))] * 3,
        out_specs=pl.BlockSpec((None, l), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, l), jnp.int32),
        interpret=True,
    )(mask.astype(jnp.int32), a.astype(jnp.int32), b.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Full intra-block sampling step (Alg. 2 phases 1–4 fused for the L2 graph)
# ---------------------------------------------------------------------------

def sample_block(z, x, k, mask_id, v_chunk=128):
    """One diffusion sampling step over an active block.

    z: [B, L, V] logits; x: [B, L] int32 current tokens; k: [B] int32
    number of tokens to commit this step. Returns (x_new, conf, x0):
    the updated sequence, per-position confidences, and per-position
    argmax predictions.
    """
    b, l, v = z.shape
    m_idx = (x == mask_id).astype(jnp.int32)                       # line 6
    conf_f, x0_f = confidence_argmax(z.reshape(b * l, v), v_chunk)  # phase 1–2
    conf = conf_f.reshape(b, l)
    x0 = x0_f.reshape(b, l)
    transfer = topk_mask(conf, m_idx, k)                           # phase 3
    x0_m = masked_select(m_idx, x0, x)                             # phase 4
    x_new = masked_select(transfer, x0_m, x)
    return x_new, conf, x0
