"""L1 Pallas kernel: bidirectional FlashAttention (paper §3.1, Alg. 1).

dLLMs use *bidirectional* attention — every position attends to every
other position with no causal mask, so there is no triangular sparsity to
exploit and the kernel streams the full K/V range for every query tile.

Hardware adaptation (DESIGN.md §4): the HBM↔VMEM schedule the paper
expresses with its prefetch engines is expressed here with BlockSpec index
maps; the online-softmax running state (m, l, acc) is the Pallas analogue
of the paper's FlashAttention accumulators held in Vector SRAM.

``interpret=True`` everywhere: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot run (see /opt/xla-example/README).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_Q = 16
DEFAULT_BLOCK_K = 16


def _flash_attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float):
    """One (batch, kv-head, group, q-tile) program instance.

    q_ref: [bq, D]; k_ref/v_ref: [Skv, D] (full key range — bidirectional);
    o_ref: [bq, D]. Streams K/V in `block_k` tiles with online softmax.
    """
    bq, d = q_ref.shape
    skv = k_ref.shape[0]
    n_kv_tiles = skv // block_k

    q = q_ref[...].astype(jnp.float32) * scale

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k_tile = pl.load(k_ref, (pl.ds(i * block_k, block_k), slice(None)))
        v_tile = pl.load(v_ref, (pl.ds(i * block_k, block_k), slice(None)))
        s = q @ k_tile.astype(jnp.float32).T                    # [bq, bk]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v_tile.astype(jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((bq,), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((bq,), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, d), dtype=jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_kv_tiles, body, (m0, l0, acc0))
    o_ref[...] = acc / l[:, None]


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def flash_attention(q, k, v, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K):
    """Bidirectional GQA FlashAttention via Pallas.

    q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D]. Returns [B, Hq, Sq, D] f32.
    Grid: (B, Hq, Sq / block_q); each program streams the full K/V range
    of its kv-head in block_k tiles.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, "GQA requires Hq % Hkv == 0"
    group = hq // hkv

    def snap(block, extent):
        """Largest divisor of `extent` that is <= the requested block."""
        block = min(block, extent)
        while extent % block:
            block -= 1
        return block

    block_q = snap(block_q, sq)
    block_k = snap(block_k, skv)
    scale = 1.0 / float(d) ** 0.5

    grid = (b, hq, sq // block_q)
    kernel = functools.partial(_flash_attn_kernel, block_k=block_k, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, d), lambda ib, ih, iq: (ib, ih, iq, 0)),
            pl.BlockSpec((None, None, skv, d), lambda ib, ih, iq: (ib, ih // group, 0, 0)),
            pl.BlockSpec((None, None, skv, d), lambda ib, ih, iq: (ib, ih // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, d),
                               lambda ib, ih, iq: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), jnp.float32),
        interpret=True,
    )(q, k, v)
