"""Build-time trainer: a masked-diffusion denoiser on synthetic tasks.

Substitution S5 (DESIGN.md): we have no LLaDA checkpoint offline, so the
artifact pipeline briefly *trains* the tiny L2 model to denoise
deterministic synthetic sequences. This gives the serving stack a model
whose generations are objectively scorable (exact-match on the
deterministic continuation — our GSM8K stand-in) and whose KV activations
exhibit the trained-transformer channel statistics BAOS exploits.

Objective: LLaDA's masked-diffusion loss. For each sequence, draw
t ~ U(0,1), mask each answer-region token independently with probability
t, and minimize 1/t-weighted cross-entropy of the original tokens at the
masked positions under the bidirectional forward pass.

Optimizer: Adam, implemented here (no optax in the offline environment).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig, GenConfig
from . import model as M


# ---------------------------------------------------------------------------
# Synthetic task corpus
# ---------------------------------------------------------------------------

TOKEN_BASE = 4   # ids 0..3 reserved (mask, pad, bos, sep)
TASK_RANGE = 48  # tokens actually used by the tasks (keeps them learnable
                 # by the tiny model; the remaining vocab still exercises
                 # the full-V sampling data path)


def make_batch(cfg: ModelConfig, gc: GenConfig, rng: np.random.Generator,
               batch: int, task: str = "mixed"):
    """Deterministic-continuation sequences of length gc.total_len.

    Tasks (prompt fills the first prompt_len tokens, continuation is a
    pure function of the prompt — exactly what exact-match can score):
      * copy: continuation repeats the prompt cyclically
      * step: s[i] = (s[0] + i*stride) mod Vr, small strides
      * interleave: even positions repeat prompt[0::2], odd repeat 1::2
    """
    vr = min(TASK_RANGE, cfg.vocab_size - TOKEN_BASE)
    n = gc.total_len
    out = np.zeros((batch, n), dtype=np.int64)
    kinds = {"copy": 0, "step": 1, "interleave": 2}
    for b in range(batch):
        kind = kinds[task] if task != "mixed" else rng.integers(0, 3)
        seq = np.zeros(n, dtype=np.int64)
        if kind == 0:
            pat = rng.integers(0, vr, size=gc.prompt_len)
            for i in range(n):
                seq[i] = pat[i % gc.prompt_len]
        elif kind == 1:
            a = rng.integers(0, vr)
            stride = rng.integers(1, 5)
            for i in range(n):
                seq[i] = (a + i * stride) % vr
        else:
            pat = rng.integers(0, vr, size=gc.prompt_len)
            half = gc.prompt_len // 2
            for i in range(n):
                src = (i // 2) % half * 2 + (i % 2)
                seq[i] = pat[src % gc.prompt_len]
        out[b] = seq + TOKEN_BASE
    return jnp.asarray(out, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# Masked-diffusion loss
# ---------------------------------------------------------------------------

def diffusion_loss(cfg: ModelConfig, gc: GenConfig, params, seqs, key):
    """LLaDA masked-diffusion objective over the answer region."""
    b, n = seqs.shape
    kt, km = jax.random.split(key)
    t = jax.random.uniform(kt, (b, 1), minval=0.05, maxval=1.0)
    u = jax.random.uniform(km, (b, n))
    answer = jnp.arange(n)[None, :] >= gc.prompt_len
    masked = jnp.logical_and(u < t, answer)
    x = jnp.where(masked, cfg.mask_id, seqs)
    logits, _, _ = M.forward_full(cfg, params, x)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, seqs[..., None], axis=-1)[..., 0]
    w = masked.astype(jnp.float32) / t  # 1/t importance weight
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(masked), 1)


# ---------------------------------------------------------------------------
# Adam (hand-rolled; no optax offline)
# ---------------------------------------------------------------------------

def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                               state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                               state["v"], grads)
    tf = t.astype(jnp.float32)
    c1, c2 = 1 - b1 ** tf, 1 - b2 ** tf

    def upd(p, m, v):
        return p - lr * (m / c1) / (jnp.sqrt(v / c2) + eps)

    return (jax.tree_util.tree_map(upd, params, m, v),
            {"m": m, "v": v, "t": t})


# ---------------------------------------------------------------------------
# Training loop
# ---------------------------------------------------------------------------

def train(cfg: ModelConfig, gc: GenConfig, steps=400, batch=32, lr=2e-3,
          seed=0, log_every=50, log=print):
    """Train the denoiser; returns (params, loss_history)."""
    M.set_attention_impl("ref")  # fast jnp attention for training only
    try:
        rng = np.random.default_rng(seed)
        key = jax.random.PRNGKey(seed)
        params = M.init_params(cfg, key)
        opt = adam_init(params)

        @jax.jit
        def step_fn(params, opt, seqs, key):
            loss, grads = jax.value_and_grad(
                lambda p: diffusion_loss(cfg, gc, p, seqs, key))(params)
            params, opt = adam_update(params, grads, opt, lr=lr)
            return params, opt, loss

        history = []
        for i in range(steps):
            seqs = make_batch(cfg, gc, rng, batch)
            key, sub = jax.random.split(key)
            params, opt, loss = step_fn(params, opt, seqs, sub)
            history.append(float(loss))
            if log_every and (i % log_every == 0 or i == steps - 1):
                log(f"train step {i:4d}  loss {float(loss):.4f}")
        return params, history
    finally:
        M.set_attention_impl("pallas")


# ---------------------------------------------------------------------------
# Evaluation: exact-match of the deterministic continuation (the GSM8K
# stand-in used by the Table 5 accuracy harness)
# ---------------------------------------------------------------------------

def exact_match(cfg: ModelConfig, gc: GenConfig, params, seqs, generated):
    """Fraction of sequences whose full answer region is reproduced."""
    ref = np.asarray(seqs)[:, gc.prompt_len:]
    got = np.asarray(generated)[:, gc.prompt_len:]
    return float(np.mean(np.all(ref == got, axis=1)))


def token_accuracy(cfg: ModelConfig, gc: GenConfig, seqs, generated):
    """Per-token accuracy over the answer region (finer-grained signal)."""
    ref = np.asarray(seqs)[:, gc.prompt_len:]
    got = np.asarray(generated)[:, gc.prompt_len:]
    return float(np.mean(ref == got))
