"""Model and generation configurations for the DART L2 stack.

The tiny presets are sized so that the whole artifact pipeline (train a
masked-diffusion denoiser, AOT-lower every executable variant, emit golden
I/O) runs in minutes on CPU while keeping every structural property the
paper's hardware cares about: bidirectional attention, GQA, blocked
diffusion with warm/refine phases, a vocabulary large enough to exercise
V_chunk tiling, and an optional MoE FFN.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    """LLaDA-style masked-diffusion transformer configuration."""

    vocab_size: int = 256
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4       # query heads
    n_kv_heads: int = 2    # GQA: kv heads (n_heads % n_kv_heads == 0)
    d_head: int = 32
    d_ff: int = 256        # SwiGLU hidden size
    # MoE (used when n_experts > 1)
    n_experts: int = 1
    top_k_experts: int = 2
    rms_eps: float = 1e-5
    mask_id: int = 0       # [MASK] token id
    pad_id: int = 1

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 1

    def n_params(self) -> int:
        """Rough parameter count (embedding tied with lm head)."""
        d, f = self.d_model, self.d_ff
        attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head \
            + self.n_heads * self.d_head * d
        ffn = 3 * d * f * max(1, self.n_experts)
        gate = d * self.n_experts if self.is_moe else 0
        per_layer = attn + ffn + gate + 2 * d
        return self.vocab_size * d + self.n_layers * per_layer + d


@dataclass(frozen=True)
class GenConfig:
    """Blocked-diffusion generation geometry (Fast-dLLM style)."""

    prompt_len: int = 16
    block_len: int = 16        # L
    n_blocks: int = 4          # N_B
    steps_per_block: int = 8   # T (denoising steps per block)
    batch: int = 4             # B

    @property
    def gen_len(self) -> int:
        return self.block_len * self.n_blocks

    @property
    def total_len(self) -> int:
        """L_tot = prompt + generated region."""
        return self.prompt_len + self.gen_len

    def block_start(self, n: int) -> int:
        return self.prompt_len + n * self.block_len

    def block_end(self, n: int) -> int:
        return self.block_start(n) + self.block_len


# The tiny presets used by `aot.py` and the accuracy harness.
TINY = ModelConfig()
TINY_MOE = ModelConfig(n_experts=4, d_ff=128)
TINY_GEN = GenConfig()


def config_dict(mc: ModelConfig, gc: GenConfig) -> dict:
    d = {"model": asdict(mc), "gen": asdict(gc)}
    d["gen"]["gen_len"] = gc.gen_len
    d["gen"]["total_len"] = gc.total_len
    return d
