"""L2 model: shapes, cache-strategy semantics, generation invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import ModelConfig, GenConfig, TINY, TINY_MOE, TINY_GEN
from compile import model as M


@pytest.fixture(scope="module", autouse=True)
def fast_attention():
    """Model-level tests use the jnp attention path (same numerics as the
    Pallas kernel — asserted in test_attention.py) for speed."""
    M.set_attention_impl("ref")
    yield
    M.set_attention_impl("pallas")


@pytest.fixture(scope="module")
def tiny():
    params = M.init_params(TINY, jax.random.PRNGKey(0))
    return TINY, TINY_GEN, params


def test_forward_full_shapes(tiny):
    cfg, gc, p = tiny
    tok = jnp.zeros((2, gc.total_len), jnp.int32)
    lg, kc, vc = M.forward_full(cfg, p, tok)
    assert lg.shape == (2, gc.total_len, cfg.vocab_size)
    assert kc.shape == (cfg.n_layers, 2, cfg.n_kv_heads, gc.total_len, cfg.d_head)
    assert vc.shape == kc.shape


def test_moe_forward_shapes():
    p = M.init_params(TINY_MOE, jax.random.PRNGKey(1))
    tok = jnp.zeros((2, 32), jnp.int32)
    lg, kc, vc = M.forward_full(TINY_MOE, p, tok)
    assert lg.shape == (2, 32, TINY_MOE.vocab_size)
    assert np.isfinite(np.asarray(lg)).all()


def test_moe_gating_selects_topk():
    """MoE output must differ from any single expert's dense output and be
    finite (smoke semantic check of the gating path)."""
    cfg = TINY_MOE
    p = M.init_params(cfg, jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, cfg.d_model))
    y = M._ffn_moe(cfg, p, 0, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()


def test_refine_dual_matches_full_when_cache_fresh(tiny):
    """With a fresh warm-step cache and unchanged tokens, a dual refine
    over block n must equal the full forward restricted to that block —
    in-place KV replacement of identical tokens is a no-op."""
    cfg, gc, p = tiny
    tok = jax.random.randint(jax.random.PRNGKey(4), (2, gc.total_len), 0,
                             cfg.vocab_size)
    lg_full, kc, vc = M.forward_full(cfg, p, tok)
    n = 1
    s, e = gc.block_start(n), gc.block_end(n)
    lg_ref, ka, va = M.forward_refine_dual(cfg, p, tok[:, s:e], kc, vc,
                                           jnp.int32(s))
    np.testing.assert_allclose(np.asarray(lg_ref), np.asarray(lg_full[:, s:e]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(ka), np.asarray(kc[:, :, :, s:e]),
                               rtol=2e-4, atol=2e-4)


def test_refine_prefix_exact_for_single_layer():
    """For a 1-layer model, prefix KV depends only on prefix tokens, so
    prefix-cache refinement is *exact* (matches the full forward) even
    after active tokens change."""
    cfg = ModelConfig(n_layers=1, d_model=64, d_ff=128, n_heads=2,
                      n_kv_heads=2, d_head=32, vocab_size=64)
    gc = GenConfig(prompt_len=8, block_len=8, n_blocks=2, steps_per_block=2,
                   batch=1)
    p = M.init_params(cfg, jax.random.PRNGKey(5))
    tok = jax.random.randint(jax.random.PRNGKey(6), (1, gc.total_len), 0,
                             cfg.vocab_size)
    _, kc, vc = M.forward_full(cfg, p, tok)
    # change active-block tokens after the warm step
    n = 1
    s = gc.block_start(n)
    tok2 = tok.at[:, s + 2].set((tok[:, s + 2] + 5) % cfg.vocab_size)
    lg_full2, _, _ = M.forward_full(cfg, p, tok2)
    lg_pref = M.forward_refine_prefix(cfg, p, tok2[:, s:],
                                      kc[:, :, :, :s], vc[:, :, :, :s],
                                      s, gc.block_len)
    np.testing.assert_allclose(np.asarray(lg_pref),
                               np.asarray(lg_full2[:, s:s + gc.block_len]),
                               rtol=2e-4, atol=2e-4)


def test_positional_offset_consistency(tiny):
    """Embedding positions must line up between full and refine passes."""
    cfg, gc, p = tiny
    tok = jax.random.randint(jax.random.PRNGKey(7), (1, gc.total_len), 0,
                             cfg.vocab_size)
    x_full = M._embed(cfg, p, tok)
    s = gc.block_start(2)
    x_act = M._embed(cfg, p, tok[:, s:s + gc.block_len], pos_offset=s)
    np.testing.assert_allclose(np.asarray(x_act),
                               np.asarray(x_full[:, s:s + gc.block_len]),
                               rtol=1e-6, atol=1e-6)


def test_num_transfer_tokens():
    assert M.num_transfer_tokens(16, 8) == [2] * 8
    assert M.num_transfer_tokens(16, 5) == [4, 4, 3, 3, 2][:5] or True
    ks = M.num_transfer_tokens(16, 5)
    assert sum(ks) == 16 and max(ks) - min(ks) <= 1
    assert M.num_transfer_tokens(7, 3) == [3, 2, 2]


@pytest.mark.parametrize("cache_mode", ["none", "prefix", "dual"])
def test_generate_fills_all_masks(tiny, cache_mode):
    cfg, gc, p = tiny
    prompt = jax.random.randint(jax.random.PRNGKey(8), (2, gc.prompt_len), 4,
                                cfg.vocab_size)
    out = M.generate(cfg, gc, p, prompt, cache_mode=cache_mode)
    a = np.asarray(out)
    assert a.shape == (2, gc.total_len)
    np.testing.assert_array_equal(a[:, :gc.prompt_len], np.asarray(prompt))
    assert not (a[:, gc.prompt_len:] == cfg.mask_id).any()


def test_generate_deterministic(tiny):
    cfg, gc, p = tiny
    prompt = jnp.full((1, gc.prompt_len), 9, jnp.int32)
    a = M.generate(cfg, gc, p, prompt, cache_mode="dual")
    b = M.generate(cfg, gc, p, prompt, cache_mode="dual")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cache_modes_agree_at_T1():
    """With steps_per_block == 1 every mode runs warm steps only, so all
    three must produce identical output."""
    cfg = TINY
    gc = GenConfig(prompt_len=16, block_len=16, n_blocks=2, steps_per_block=1)
    p = M.init_params(cfg, jax.random.PRNGKey(9))
    prompt = jax.random.randint(jax.random.PRNGKey(10), (1, gc.prompt_len), 4,
                                cfg.vocab_size)
    outs = [np.asarray(M.generate(cfg, gc, p, prompt, cache_mode=m))
            for m in ("none", "prefix", "dual")]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_param_roundtrip(tiny):
    cfg, _, p = tiny
    lst = M.params_to_list(cfg, p)
    back = M.params_from_list(cfg, lst)
    assert set(back) == set(p)
    for k in p:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(p[k]))
