"""L1 sampling-engine Pallas kernels vs ref oracles (paper Alg. 2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import sampling as S
from compile.kernels import ref as R


# ---------------------------------------------------------------------------
# Phase 1: Stable-Max confidence + argmax
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,v,chunk", [
    (4, 64, 64), (4, 64, 16), (8, 256, 128), (2, 512, 64),
])
def test_confidence_matches_ref(n, v, chunk):
    z = jax.random.normal(jax.random.PRNGKey(0), (n, v)) * 4
    c1, i1 = S.confidence_argmax(z, v_chunk=chunk)
    c2, i2 = R.stable_max_confidence_ref(z)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_confidence_chunk_invariance():
    """V_chunk is a pure tiling knob — results must be identical."""
    z = jax.random.normal(jax.random.PRNGKey(1), (4, 256)) * 3
    base = S.confidence_argmax(z, v_chunk=256)
    for chunk in (16, 32, 64, 128):
        got = S.confidence_argmax(z, v_chunk=chunk)
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(base[0]),
                                   rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(base[1]))


def test_confidence_is_softmax_max():
    """Eq. 3: conf == softmax(z)[argmax]."""
    z = jax.random.normal(jax.random.PRNGKey(2), (6, 128)) * 5
    conf, idx = S.confidence_argmax(z, v_chunk=32)
    probs = jax.nn.softmax(z, axis=-1)
    expect = probs[jnp.arange(6), idx]
    np.testing.assert_allclose(np.asarray(conf), np.asarray(expect), rtol=1e-5)


def test_confidence_tie_keeps_earlier_index():
    z = jnp.zeros((1, 64)).at[0, 10].set(2.0).at[0, 40].set(2.0)
    _, idx = S.confidence_argmax(z, v_chunk=16)
    assert int(idx[0]) == 10


def test_confidence_large_logits_stable():
    """Stable-Max must not overflow on large logits (the reason the
    m-subtraction exists)."""
    z = jnp.full((2, 64), 300.0).at[0, 3].set(400.0)
    conf, idx = S.confidence_argmax(z, v_chunk=16)
    assert np.isfinite(np.asarray(conf)).all()
    assert int(idx[0]) == 3


# ---------------------------------------------------------------------------
# Phase 3: streaming top-k
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    l=st.sampled_from([8, 16, 32]),
    k=st.integers(0, 32),
    seed=st.integers(0, 2 ** 16),
    mask_p=st.floats(0.0, 1.0),
)
def test_topk_property(l, k, seed, mask_p):
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    conf = jax.random.uniform(keys[0], (1, l))
    mask = (jax.random.uniform(keys[1], (1, l)) < mask_p).astype(jnp.int32)
    kk = jnp.array([min(k, l)], dtype=jnp.int32)
    got = np.asarray(S.topk_mask(conf, mask, kk))[0] != 0
    ref = np.asarray(R.topk_mask_ref(conf[0], mask[0] != 0, min(k, l)))
    np.testing.assert_array_equal(got, ref)
    # invariants: count == min(k, #eligible); selected ⊆ eligible
    assert got.sum() == min(min(k, l), int(mask.sum()))
    assert not np.any(got & ~(np.asarray(mask)[0] != 0))


def test_topk_selects_highest():
    conf = jnp.asarray([[0.1, 0.9, 0.3, 0.8, 0.2, 0.7, 0.0, 0.5]])
    mask = jnp.ones((1, 8), jnp.int32)
    got = np.asarray(S.topk_mask(conf, mask, jnp.asarray([3], jnp.int32)))[0]
    np.testing.assert_array_equal(got, [0, 1, 0, 1, 0, 1, 0, 0])


def test_topk_respects_mask():
    conf = jnp.asarray([[0.9, 0.8, 0.7, 0.6]])
    mask = jnp.asarray([[0, 1, 0, 1]], jnp.int32)  # best two are ineligible
    got = np.asarray(S.topk_mask(conf, mask, jnp.asarray([2], jnp.int32)))[0]
    np.testing.assert_array_equal(got, [0, 1, 0, 1])


def test_topk_k_zero():
    conf = jnp.ones((1, 8))
    mask = jnp.ones((1, 8), jnp.int32)
    got = np.asarray(S.topk_mask(conf, mask, jnp.asarray([0], jnp.int32)))[0]
    assert got.sum() == 0


# ---------------------------------------------------------------------------
# Phase 4: masked select + full sample_block flow
# ---------------------------------------------------------------------------

def test_masked_select():
    m = jnp.asarray([[1, 0, 1, 0]], jnp.int32)
    a = jnp.asarray([[10, 11, 12, 13]], jnp.int32)
    b = jnp.asarray([[20, 21, 22, 23]], jnp.int32)
    got = np.asarray(S.masked_select(m, a, b))[0]
    np.testing.assert_array_equal(got, [10, 21, 12, 23])


def test_sample_block_commits_exactly_k():
    b, l, v, mask_id = 2, 16, 64, 0
    z = jax.random.normal(jax.random.PRNGKey(3), (b, l, v)) * 3
    x = jnp.full((b, l), mask_id, jnp.int32).at[:, :4].set(7)
    k = jnp.asarray([3, 5], jnp.int32)
    x_new, conf, x0 = S.sample_block(z, x, k, mask_id)
    before = np.asarray(x) == mask_id
    after = np.asarray(x_new) == mask_id
    committed = before & ~after
    np.testing.assert_array_equal(committed.sum(axis=1), np.asarray(k))
    # unmasked positions never change
    np.testing.assert_array_equal(np.asarray(x_new)[~before],
                                  np.asarray(x)[~before])
    # committed tokens are the argmax predictions
    idx = np.asarray(x0)
    np.testing.assert_array_equal(np.asarray(x_new)[committed], idx[committed])


def test_sample_block_progressive_unmask():
    """Iterating sample_block fully unmasks in ceil(L/k) steps."""
    b, l, v, mask_id = 1, 8, 32, 0
    x = jnp.full((b, l), mask_id, jnp.int32)
    for step in range(4):
        z = jax.random.normal(jax.random.PRNGKey(step), (b, l, v))
        x, _, _ = S.sample_block(z, x, jnp.asarray([2], jnp.int32), mask_id)
        assert int((np.asarray(x) == mask_id).sum()) == l - 2 * (step + 1)
    assert not (np.asarray(x) == mask_id).any()
