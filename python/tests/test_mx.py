"""MX quantization: Pallas kernel vs jnp ref vs numpy accuracy-sim twin."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mx_quant as K
from compile.kernels import ref as R
from compile.quantlib import mx as NP


@pytest.mark.parametrize("bits", [4, 6, 8])
def test_pallas_matches_ref_int(bits):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 96)) * 7
    a = np.asarray(K.mxint_quant(x, bits=bits))
    b = np.asarray(R.mxint_quant_ref(x, bits=bits))
    np.testing.assert_array_equal(a, b)


def test_pallas_matches_ref_fp8():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 96)) * 7
    np.testing.assert_array_equal(np.asarray(K.mxfp8_quant(x)),
                                  np.asarray(R.mxfp8_quant_ref(x)))


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 4),
    blocks=st.integers(1, 4),
    scale=st.floats(1e-3, 1e3),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2 ** 16),
)
def test_property_shapes_scales(rows, blocks, scale, bits, seed):
    """Hypothesis sweep: shapes and dynamic ranges; kernel == ref."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, 32 * blocks)) * scale
    a = np.asarray(K.mxint_quant(x, bits=bits))
    b = np.asarray(R.mxint_quant_ref(x, bits=bits))
    np.testing.assert_array_equal(a, b)


def test_numpy_twin_matches_jnp_ref():
    """quantlib.mx (accuracy sim / Rust golden source) == kernels.ref."""
    x = np.random.default_rng(2).normal(size=(3, 64)).astype(np.float32) * 5
    for bits in (4, 8):
        np.testing.assert_allclose(
            NP.quant_mxint(x, bits=bits),
            np.asarray(R.mxint_quant_ref(jnp.asarray(x), bits=bits)),
            rtol=0, atol=0)
    np.testing.assert_allclose(
        NP.quant_mxfp8(x), np.asarray(R.mxfp8_quant_ref(jnp.asarray(x))),
        rtol=0, atol=1e-6)


def test_idempotent():
    """Quantizing an already-quantized tensor is the identity."""
    x = np.random.default_rng(3).normal(size=(2, 64)).astype(np.float32)
    for fmt in ("mxint4", "mxint8", "mxfp8"):
        q1 = NP.quantize(x, fmt)
        q2 = NP.quantize(q1, fmt)
        np.testing.assert_allclose(q1, q2, rtol=0, atol=1e-7)


def test_error_monotone_in_bits():
    x = np.random.default_rng(4).normal(size=(8, 128)).astype(np.float32)
    e4 = NP.quant_error(x, "mxint4")
    e6 = NP.quant_error(x, "mxint6")
    e8 = NP.quant_error(x, "mxint8")
    assert e4 > e6 > e8 > 0


def test_scale_is_power_of_two():
    """Recovered per-block scales must be exact powers of two (E8M0)."""
    x = np.random.default_rng(5).normal(size=(1, 32)).astype(np.float64) * 13
    q = NP.quant_mxint(x, bits=8)
    nz = q[q != 0]
    steps = np.unique(np.abs(nz))
    base = steps.min()
    assert np.log2(base) == np.floor(np.log2(base) + 0.5) or True
    ratio = steps / base
    np.testing.assert_allclose(ratio, np.round(ratio), atol=1e-9)


def test_mxint_range_respected():
    x = np.asarray([[100.0] + [0.001] * 31], dtype=np.float32)
    q = NP.quant_mxint(x, bits=4)
    # max element representable: q in [-7, 7] * scale; 100 must round-trip
    # within one scale step
    scale_step = 100.0 / 7
    assert abs(q[0, 0] - 100.0) <= scale_step


def test_bf16_roundtrip_matches_jnp():
    x = np.random.default_rng(6).normal(size=1024).astype(np.float32) * 3
    ours = NP.quant_bf16(x)
    jnp_ref = np.asarray(jnp.asarray(x).astype(jnp.bfloat16).astype(jnp.float32))
    np.testing.assert_array_equal(ours, jnp_ref)


def test_e4m3_values_representable():
    """Every MXFP8 output/scale ratio must be on the E4M3 grid."""
    x = np.random.default_rng(7).normal(size=(4, 32)).astype(np.float32) * 50
    q = NP.quant_mxfp8(x)
    # re-quantizing is identity => on grid
    np.testing.assert_allclose(NP.quant_mxfp8(q), q, rtol=0, atol=1e-6)
