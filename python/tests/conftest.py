import os
import sys

import numpy as np
import pytest

# make `compile` importable when pytest is run from python/ or repo root
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def artifacts_dir():
    d = os.path.join(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))), "artifacts")
    return d
