"""Artifact manifest self-consistency (requires `make artifacts` first;
skipped otherwise). The same goldens are consumed by the Rust integration
tests, so this pins both sides to one ground truth."""

import json
import os
import struct

import numpy as np
import pytest


@pytest.fixture(scope="module")
def manifest(artifacts_dir):
    path = os.path.join(artifacts_dir, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f), artifacts_dir


def test_manifest_format(manifest):
    m, _ = manifest
    assert m["format"] == "dart-manifest-v1"
    assert m["config"]["model"]["vocab_size"] > 0
    assert set(m["param_order"]) >= {"embed", "wq", "wk", "wv", "wo"}


def test_all_hlo_files_exist_and_parse_header(manifest):
    m, d = manifest
    for name, ex in m["executables"].items():
        p = os.path.join(d, ex["file"])
        assert os.path.exists(p), name
        head = open(p).read(200)
        assert "HloModule" in head, name


def test_executable_shapes_consistent(manifest):
    m, _ = manifest
    cfg = m["config"]["model"]
    gc = m["config"]["gen"]
    for b in m["batches"]:
        ex = m["executables"][f"full_b{b}"]
        assert ex["inputs"][0][2] == [b, gc["total_len"]]
        assert ex["outputs"][0][2] == [b, gc["total_len"], cfg["vocab_size"]]
        exd = m["executables"][f"refine_dual_b{b}"]
        assert exd["outputs"][0][2] == [b, gc["block_len"], cfg["vocab_size"]]


def test_weights_bin_parses_and_matches_npz(manifest):
    m, d = manifest
    path = os.path.join(d, m["weights_file"])
    data = open(path, "rb").read()
    assert data[:8] == b"DARTWTS1"
    off = 8
    (count,) = struct.unpack_from("<I", data, off); off += 4
    assert count == len(m["param_order"])
    npz = np.load(os.path.join(d, "weights.npz"))
    for _ in range(count):
        (nlen,) = struct.unpack_from("<I", data, off); off += 4
        name = data[off:off + nlen].decode(); off += nlen
        (ndim,) = struct.unpack_from("<I", data, off); off += 4
        dims = struct.unpack_from(f"<{ndim}Q", data, off); off += 8 * ndim
        n = int(np.prod(dims))
        arr = np.frombuffer(data, dtype="<f4", count=n, offset=off)
        off += 4 * n
        np.testing.assert_array_equal(arr.reshape(dims), npz[name])
    assert off == len(data)


def test_goldens_recompute(manifest):
    """Re-run the golden forward with cached weights; summaries must match
    the manifest bit-for-bit-ish."""
    m, d = manifest
    import jax.numpy as jnp
    from compile.configs import TINY, TINY_GEN
    from compile import model as M

    npz = np.load(os.path.join(d, "weights.npz"))
    params = {k: jnp.asarray(v) for k, v in npz.items()}
    M.set_attention_impl("ref")
    try:
        gc, cfg = TINY_GEN, TINY
        tok = np.arange(4 * gc.total_len, dtype=np.int32) \
            .reshape(4, gc.total_len) % m["goldens"]["full_tokens_mod"]
        lg, kc, vc = M.forward_full(cfg, params, jnp.asarray(tok))
        g = m["goldens"]["full_logits"]
        assert abs(float(np.asarray(lg, np.float64).sum()) - g["sum"]) < \
            1e-3 * max(1.0, abs(g["sum"]))
        np.testing.assert_allclose(
            np.asarray(lg).reshape(-1)[:8], g["first8"], rtol=1e-4, atol=1e-4)
    finally:
        M.set_attention_impl("pallas")


def test_sampling_goldens_selfconsistent(manifest):
    m, _ = manifest
    g = m["goldens"]["sampling"]
    b, l, v = g["b"], g["l"], g["v"]
    z = np.asarray(g["z"], np.float32).reshape(b, l, v)
    conf = np.asarray(g["conf"], np.float32).reshape(b, l)
    idx = np.asarray(g["argmax"], np.int64).reshape(b, l)
    # conf == softmax max, idx == argmax
    zm = z.max(axis=-1)
    denom = np.exp(z - zm[..., None]).sum(axis=-1)
    np.testing.assert_allclose(conf, 1.0 / denom, rtol=1e-5)
    np.testing.assert_array_equal(idx, z.argmax(axis=-1))
    tm = np.asarray(g["transfer_mask"], np.int64).reshape(b, l)
    k = np.asarray(g["k"])
    np.testing.assert_array_equal(tm.sum(axis=1), np.minimum(
        k, (np.asarray(g["x"]).reshape(b, l) == g["mask_id"]).sum(axis=1)))
