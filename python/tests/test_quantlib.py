"""quantlib: GPTQ / clipping / BAOS / rotation semantics (paper §4.3–4.4)."""

import numpy as np
import pytest

from compile.quantlib import mx, baos, rotation, gptq


@pytest.fixture(scope="module")
def wx():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(32, 128))
    # a few outlier input channels, as in real transformer activations
    x = rng.normal(size=(512, 128))
    x[:, 7] *= 8
    x[:, 90] *= 5
    return w, x


def _output_err(w, q, x):
    return float(np.linalg.norm(x @ (w - q).T))


def test_gptq_beats_rtn(wx):
    w, x = wx
    q_rtn = gptq.rtn_quantize(w, bits=4)
    q_gptq = gptq.gptq_quantize(w, x, bits=4)
    assert _output_err(w, q_gptq, x) < _output_err(w, q_rtn, x)


def test_clip_search_beats_plain_gptq(wx):
    w, x = wx
    q = gptq.gptq_quantize(w, x, bits=4)
    qx = gptq.gptq_quantize(w, x, bits=4, clip_mode="x")
    qy = gptq.gptq_quantize(w, x, bits=4, clip_mode="y")
    base = _output_err(w, q, x)
    assert _output_err(w, qx, x) < base * 1.02  # x-clip ~helps
    assert _output_err(w, qy, x) < base         # y-clip targets exactly this


def test_yclip_minimizes_output_not_weight_err(wx):
    """Eq. 7: y-clip may sacrifice weight error for output error."""
    w, x = wx
    qx = gptq.gptq_quantize(w, x, bits=4, clip_mode="x")
    qy = gptq.gptq_quantize(w, x, bits=4, clip_mode="y")
    assert _output_err(w, qy, x) <= _output_err(w, qx, x) * 1.05


def test_gptq_8bit_near_lossless(wx):
    w, x = wx
    q = gptq.gptq_quantize(w, x, bits=8)
    rel = np.linalg.norm(w - q) / np.linalg.norm(w)
    assert rel < 0.01


def test_clip_grid_percentiles_valid(wx):
    w, _ = wx
    p = gptq.search_clip(w[:, :32], None, bits=4, mode="x")
    assert p.shape == (32,)
    assert np.all((p >= 0.5) & (p <= 1.0))


# ---------------------------------------------------------------------------
# BAOS
# ---------------------------------------------------------------------------

def _kv_with_outliers(seed=1, shape=(2, 2, 2, 16, 32), chans=(3, 17)):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape).astype(np.float32)
    for c in chans:
        x[..., c] = x[..., c] * 15 + 4  # magnitude + offset outliers
    return x


def test_baos_beats_naive_on_outliers():
    """The Table 5 headline ordering: BAOS < naive KV4 error under
    channel-wise outliers (13–19x the mean, §4.4)."""
    k = _kv_with_outliers()
    st = baos.BaosState("mean", 1.0)
    st.calibrate(k, k)
    kq, _ = st.apply(k, k, "mxint4")
    kn = mx.quantize(k, "mxint4")
    assert np.linalg.norm(kq - k) < np.linalg.norm(kn - k)


@pytest.mark.parametrize("variant", ["mean", "minmax"])
@pytest.mark.parametrize("alpha", [1.0, 0.9, 0.6])
def test_baos_variants_finite_and_improve(variant, alpha):
    k = _kv_with_outliers(seed=2)
    st = baos.BaosState(variant, alpha)
    st.calibrate(k, k)
    kq, _ = st.apply(k, k, "mxint4")
    assert np.isfinite(kq).all()
    kn = mx.quantize(k, "mxint4")
    assert np.linalg.norm(kq - k) < np.linalg.norm(kn - k)


def test_baos_factors_shape_and_reuse():
    """Factors reduce over S (shape B,H,1,D) and are *reused* across
    refinement steps — the zero-overhead warm-step calibration."""
    k = _kv_with_outliers(shape=(1, 2, 4, 8, 32))
    st = baos.BaosState("mean", 1.0)
    st.calibrate(k, k)
    assert st.c_k.shape == (1, 2, 4, 1, 32)
    c0, f0 = st.c_k.copy(), st.f_k.copy()
    # refinement-step tensor with drifted stats; apply() must not recalibrate
    st.apply(k * 1.5, k * 1.5, "mxint4")
    np.testing.assert_array_equal(st.c_k, c0)
    np.testing.assert_array_equal(st.f_k, f0)


def test_baos_alpha_compresses_dynamic_range():
    """Eq. 9: alpha < 1 damps outlier-dominated channels' factors."""
    k = _kv_with_outliers(seed=3)
    s1 = baos.BaosState("mean", 1.0); s1.calibrate(k, k)
    s6 = baos.BaosState("mean", 0.6); s6.calibrate(k, k)
    r1 = s1.f_k.max() / s1.f_k.min()
    r6 = s6.f_k.max() / s6.f_k.min()
    assert r6 < r1


def test_baos_centering_exactness_fp32():
    """Without quantization the smooth→unsmooth round trip is lossless."""
    k = _kv_with_outliers(seed=4)
    st = baos.BaosState("minmax", 0.9)
    st.calibrate(k, k)
    kq, vq = st.apply(k, k, "fp32")
    np.testing.assert_allclose(kq, k, rtol=1e-5, atol=1e-5)


def test_outlier_stability_metric():
    k_warm = _kv_with_outliers(seed=5)
    steps = [k_warm + np.random.default_rng(i).normal(
        size=k_warm.shape).astype(np.float32) * 0.1 for i in range(4)]
    frac = baos.outlier_channel_stability(k_warm, steps, top=8)
    assert frac > 0.7  # the paper's §4.4.1 observation on stable outliers


# ---------------------------------------------------------------------------
# Rotation (QuaRot baseline)
# ---------------------------------------------------------------------------

def test_hadamard_orthonormal():
    for n in (2, 8, 32):
        h = rotation.hadamard(n)
        np.testing.assert_allclose(h @ h.T, np.eye(n), atol=1e-6)


def test_hadamard_requires_pow2():
    with pytest.raises(ValueError):
        rotation.hadamard(24)


def test_rotation_lossless_without_quant():
    x = np.random.default_rng(6).normal(size=(2, 3, 4, 8, 32)).astype(np.float32)
    got = rotation.rotate_quant(x, "fp32")
    np.testing.assert_allclose(got, x, rtol=1e-5, atol=1e-5)


def test_rotation_spreads_outliers():
    """After rotation, per-channel max magnitudes flatten."""
    x = _kv_with_outliers(seed=7)
    h = rotation.hadamard(32)
    xr = x @ h
    spread = lambda a: np.abs(a).max(axis=tuple(range(a.ndim - 1)))
    assert spread(xr).std() < spread(x).std()
