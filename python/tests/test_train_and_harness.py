"""Trainer + Table 5 harness machinery (quick smokes; the full Table 5 run
is `make table5`, recorded in EXPERIMENTS.md)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import ModelConfig, GenConfig, TINY, TINY_GEN
from compile import model as M
from compile import train as T
from compile.quantlib import harness as H


SMALL = ModelConfig(vocab_size=64, d_model=64, n_layers=1, n_heads=2,
                    n_kv_heads=2, d_head=32, d_ff=64)
SMALL_GEN = GenConfig(prompt_len=8, block_len=8, n_blocks=2,
                      steps_per_block=2)


def test_make_batch_deterministic_continuations():
    rng = np.random.default_rng(0)
    seqs = np.asarray(T.make_batch(TINY, TINY_GEN, rng, 8))
    assert seqs.shape == (8, TINY_GEN.total_len)
    assert seqs.min() >= T.TOKEN_BASE
    assert seqs.max() < T.TOKEN_BASE + T.TASK_RANGE


def test_make_batch_tasks_distinct():
    rng = np.random.default_rng(1)
    a = np.asarray(T.make_batch(TINY, TINY_GEN, rng, 4, task="copy"))
    # copy: continuation repeats the prompt cyclically
    p = TINY_GEN.prompt_len
    np.testing.assert_array_equal(a[:, p:2 * p], a[:, :p])


def test_diffusion_loss_finite_and_positive():
    p = M.init_params(SMALL, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    seqs = T.make_batch(SMALL, SMALL_GEN, rng, 4)
    loss = T.diffusion_loss(SMALL, SMALL_GEN, p, seqs, jax.random.PRNGKey(1))
    assert np.isfinite(float(loss)) and float(loss) > 0


def test_short_training_reduces_loss():
    params, hist = T.train(SMALL, SMALL_GEN, steps=40, batch=16, lr=3e-3,
                           log_every=0, log=lambda *a: None)
    assert np.mean(hist[-8:]) < np.mean(hist[:8])


def test_adam_step_changes_params():
    p = {"w": jnp.ones((4, 4))}
    g = {"w": jnp.full((4, 4), 0.5)}
    st = T.adam_init(p)
    p2, st2 = T.adam_update(p, g, st, lr=1e-2)
    assert not np.allclose(np.asarray(p2["w"]), 1.0)
    assert int(st2["t"]) == 1


# ---------------------------------------------------------------------------
# Harness machinery
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_trained():
    params, _ = T.train(SMALL, SMALL_GEN, steps=60, batch=16, lr=3e-3,
                        log_every=0, log=lambda *a: None)
    return params


def test_capture_calib_matches_forward(small_trained):
    """The calibration capture must reproduce forward_full's logits."""
    M.set_attention_impl("ref")
    try:
        tok = jnp.arange(2 * SMALL_GEN.total_len, dtype=jnp.int32) \
            .reshape(2, -1) % SMALL.vocab_size
        caps, logits_cap = H.capture_calib(SMALL, small_trained, tok)
        logits, _, _ = M.forward_full(SMALL, small_trained, tok)
        np.testing.assert_allclose(logits_cap, np.asarray(logits),
                                   rtol=2e-4, atol=2e-4)
        assert set(caps) == set(H.WEIGHT_NAMES)
        assert caps["wq"][0].shape == (2 * SMALL_GEN.total_len, SMALL.d_model)
    finally:
        M.set_attention_impl("pallas")


def test_quantize_weights_modes(small_trained):
    tok = jnp.arange(2 * SMALL_GEN.total_len, dtype=jnp.int32) \
        .reshape(2, -1) % SMALL.vocab_size
    caps, _ = H.capture_calib(SMALL, small_trained, tok)
    for mode in ("rtn", "gptq", "gptq_xclip"):
        q = H.quantize_weights(SMALL, small_trained, caps, mode=mode)
        # weights changed but finite; norms within 25%
        for n in H.WEIGHT_NAMES:
            a, b = np.asarray(small_trained[n]), np.asarray(q[n])
            assert np.isfinite(b).all()
            assert 0.75 < np.linalg.norm(b) / np.linalg.norm(a) < 1.25


def test_kv_transforms_run_in_generate(small_trained):
    prompt = jnp.full((1, SMALL_GEN.prompt_len), 9, jnp.int32)
    for tr in (H.kv_naive(), H.kv_quarot(), H.kv_baos("mean", 0.9)):
        out = H.evaluate(SMALL, SMALL_GEN, small_trained,
                         jnp.tile(prompt, (1, SMALL_GEN.total_len //
                                           SMALL_GEN.prompt_len)),
                         cache_mode="dual", kv_transform=tr)
        assert 0.0 <= out["token_acc"] <= 1.0


def test_sampling_precisions_preserve_argmax_mostly(small_trained):
    """BF16/MXFP8 logit quantization rarely flips the argmax (the paper's
    'low precision preserves generation quality' premise)."""
    z = np.random.default_rng(3).normal(size=(64, 64)).astype(np.float32) * 4
    base = z.argmax(axis=-1)
    for name, fn in (("bf16", H.logits_bf16), ("mxfp8", H.logits_mxfp8)):
        zq = np.asarray(fn(jnp.asarray(z)))
        agree = float(np.mean(zq.argmax(axis=-1) == base))
        assert agree > 0.9, (name, agree)
