"""L1 FlashAttention Pallas kernel vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import flash_attention
from compile.kernels.ref import attention_ref


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d", [
    (1, 1, 1, 16, 16, 8),
    (2, 4, 2, 32, 48, 16),
    (1, 8, 2, 16, 80, 32),   # GQA group 4, long kv (warm-step shape)
    (3, 2, 2, 48, 16, 32),   # MHA, query longer than kv
])
def test_matches_ref(b, hq, hkv, sq, skv, d):
    q = _rand(0, (b, hq, sq, d))
    k = _rand(1, (b, hkv, skv, d))
    v = _rand(2, (b, hkv, skv, d))
    out = flash_attention(q, k, v)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_bidirectional_no_causal_mask():
    """A query at position 0 must see keys at later positions — the dLLM
    structural property AR kernels break."""
    b, h, s, d = 1, 1, 16, 8
    q = jnp.zeros((b, h, s, d))
    k = jnp.zeros((b, h, s, d)).at[0, 0, s - 1].set(10.0)
    v = jnp.zeros((b, h, s, d)).at[0, 0, s - 1].set(1.0)
    out = flash_attention(q, k, v)
    # all-zero queries → uniform attention → every position mixes the
    # last value row; causal masking would zero out position 0's view
    assert float(out[0, 0, 0, 0]) > 0.0


def test_tile_invariance():
    """Result must not depend on the streaming tile sizes."""
    q, k, v = _rand(3, (2, 2, 32, 16)), _rand(4, (2, 2, 64, 16)), _rand(5, (2, 2, 64, 16))
    a = flash_attention(q, k, v, block_q=16, block_k=16)
    b = flash_attention(q, k, v, block_q=8, block_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 2),
    hkv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2]),
    sq=st.sampled_from([8, 16]),
    skv=st.sampled_from([8, 24]),
    d=st.sampled_from([8, 32]),
    seed=st.integers(0, 2 ** 16),
)
def test_property_sweep(b, hkv, group, sq, skv, d, seed):
    """Hypothesis sweep over shapes (GQA groups, uneven sq/skv)."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(keys[0], (b, hkv * group, sq, d))
    k = jax.random.normal(keys[1], (b, hkv, skv, d))
    v = jax.random.normal(keys[2], (b, hkv, skv, d))
    out = flash_attention(q, k, v, block_q=8, block_k=8)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_scale_is_rsqrt_d():
    """Softmax scaling must be 1/sqrt(d): compare against hand-rolled."""
    q, k, v = _rand(6, (1, 1, 8, 16)), _rand(7, (1, 1, 8, 16)), _rand(8, (1, 1, 8, 16))
    out = flash_attention(q, k, v, block_q=8, block_k=8)
    s = (q[0, 0] @ k[0, 0].T) / jnp.sqrt(16.0)
    ref = jax.nn.softmax(s, axis=-1) @ v[0, 0]
    np.testing.assert_allclose(np.asarray(out[0, 0]), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
